//! Entropy stage under the wire framing: delta+varint index packing,
//! an in-house LZ77 byte compressor, and the per-frame policy that
//! decides when either pays for itself.
//!
//! ScaleCom's sparse frames carry strictly increasing u32 indices, so a
//! delta+varint encoding (first index raw, then `idx[i] - idx[i-1] - 1`)
//! shrinks the index half of the payload by 2-4x at paper-like top-k
//! rates — and makes "strictly increasing" structural: a decoded delta
//! stream cannot violate it. On top of that, [`FrameCodec`] can run an
//! adaptive byte-compression pass ([`Algo::Lz1`]/[`Algo::Lz2`], an LZ4
//! style token format implemented here because the build is offline and
//! dependency-free) guarded so it only ever ships a compressed body that
//! is *smaller* than the raw one — high-entropy payloads (random f32
//! mantissas) fall back to raw after a cheap prefix probe.
//!
//! Everything here observes the wire module's decode-under-adversity
//! contract: decoding never panics, never allocates more than the
//! declared (and capped) output size, and rejects truncation, garbage,
//! and "zip bomb" length fields with errors.
//!
//! The f32 payload bits are never transformed — only the byte envelope
//! changes — so the backend determinism contract survives compression.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

// ---------------------------------------------------------------------------
// varint + delta primitives
// ---------------------------------------------------------------------------

/// Append `v` as LEB128 (7 bits per byte, low to high; at most 5 bytes).
pub fn put_varint_u32(out: &mut Vec<u8>, mut v: u32) {
    while v >= 0x80 {
        out.push((v as u8 & 0x7F) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Encoded size of `v` as a varint.
pub fn varint_len(v: u32) -> usize {
    match v {
        0..=0x7F => 1,
        0x80..=0x3FFF => 2,
        0x4000..=0x1F_FFFF => 3,
        0x20_0000..=0xFFF_FFFF => 4,
        _ => 5,
    }
}

/// Read one varint u32 at `*pos`, advancing it. Rejects truncation and
/// encodings that overflow 32 bits.
pub fn read_varint_u32(buf: &[u8], pos: &mut usize) -> anyhow::Result<u32> {
    let mut v: u32 = 0;
    for shift in 0..5u32 {
        let b = *buf
            .get(*pos)
            .ok_or_else(|| anyhow::anyhow!("codec: truncated varint"))?;
        *pos += 1;
        let payload = (b & 0x7F) as u32;
        if shift == 4 && payload > 0x0F {
            anyhow::bail!("codec: varint overflows u32");
        }
        v |= payload << (7 * shift);
        if b & 0x80 == 0 {
            return Ok(v);
        }
    }
    anyhow::bail!("codec: varint longer than 5 bytes")
}

/// True when `idx` is strictly increasing (the packable shape).
pub fn strictly_increasing(idx: &[u32]) -> bool {
    idx.windows(2).all(|w| w[0] < w[1])
}

/// Append a strictly increasing index set as delta+varints: the first
/// index verbatim, then `idx[i] - idx[i-1] - 1` (the `-1` is free — gaps
/// are at least 1 — and lets a decoder rebuild a strictly increasing set
/// by construction).
pub fn put_index_deltas(out: &mut Vec<u8>, indices: &[u32]) {
    debug_assert!(strictly_increasing(indices));
    let mut prev: u32 = 0;
    for (k, &i) in indices.iter().enumerate() {
        let d = if k == 0 { i } else { i - prev - 1 };
        put_varint_u32(out, d);
        prev = i;
    }
}

/// Exact byte length [`put_index_deltas`] would append.
pub fn index_deltas_len(indices: &[u32]) -> usize {
    let mut prev: u32 = 0;
    let mut total = 0usize;
    for (k, &i) in indices.iter().enumerate() {
        let d = if k == 0 { i } else { i - prev - 1 };
        total += varint_len(d);
        prev = i;
    }
    total
}

/// Read `count` delta+varint indices at `*pos`. The result is strictly
/// increasing by construction; an accumulated index past `u32::MAX` is
/// rejected (in u64, overflow-proof).
pub fn read_index_deltas(buf: &[u8], pos: &mut usize, count: usize) -> anyhow::Result<Vec<u32>> {
    let mut idx = Vec::with_capacity(count);
    let mut acc: u64 = 0;
    for k in 0..count {
        let d = read_varint_u32(buf, pos)? as u64;
        acc = if k == 0 { d } else { acc + d + 1 };
        anyhow::ensure!(acc <= u32::MAX as u64, "codec: packed index overflows u32");
        idx.push(acc as u32);
    }
    Ok(idx)
}

// ---------------------------------------------------------------------------
// byte compressor ("slz": LZ4-style token stream, dependency-free)
// ---------------------------------------------------------------------------
//
// sequence := [u8 token] [literal-len ext] [literals]
//            ([u16 LE offset] [match-len ext])?
// token    := (literal_len.min(15) << 4) | match_code.min(15)
//
// A nibble of 15 is followed by 255-run extension bytes (LZ4's scheme).
// `match_len = match_code + 4`. The final sequence of a stream carries
// literals only — the decoder observes end-of-input after the literal
// run and stops, so no explicit terminator byte is spent.

const LZ_MIN_MATCH: usize = 4;
/// The compressor leaves the last bytes of its input as literals so
/// match extension never reads past the end.
const LZ_TAIL: usize = 5;
const LZ_MAX_OFFSET: usize = 0xFFFF;

/// Byte-compression algorithm of one frame body. `Lz1`/`Lz2` share one
/// format and differ only in search effort (hash-table size and how fast
/// the matcher skips over incompressible runs), so a decoder needs no
/// per-level logic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// No byte-compression pass (the body ships as encoded).
    Raw,
    /// Fast greedy match search (4K hash slots) — small/mid bodies.
    Lz1,
    /// Deeper search (64K hash slots) — large bodies where a better
    /// ratio amortizes the extra table work.
    Lz2,
}

impl Algo {
    pub const COUNT: usize = 3;
    pub const ALL: [Algo; Algo::COUNT] = [Algo::Raw, Algo::Lz1, Algo::Lz2];

    pub fn to_byte(self) -> u8 {
        match self {
            Algo::Raw => 0,
            Algo::Lz1 => 1,
            Algo::Lz2 => 2,
        }
    }

    pub fn from_byte(b: u8) -> anyhow::Result<Algo> {
        match b {
            0 => Ok(Algo::Raw),
            1 => Ok(Algo::Lz1),
            2 => Ok(Algo::Lz2),
            other => anyhow::bail!("codec: unknown compression algorithm byte {other}"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Algo::Raw => "raw",
            Algo::Lz1 => "lz1",
            Algo::Lz2 => "lz2",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Algo> {
        match s {
            "raw" => Ok(Algo::Raw),
            "lz1" => Ok(Algo::Lz1),
            "lz2" => Ok(Algo::Lz2),
            other => anyhow::bail!(
                "unknown compression algorithm '{other}' (expected raw | lz1 | lz2)"
            ),
        }
    }

    fn index(self) -> usize {
        self.to_byte() as usize
    }

    fn hash_bits(self) -> u32 {
        match self {
            Algo::Raw => 0,
            Algo::Lz1 => 12,
            Algo::Lz2 => 16,
        }
    }

    /// After `1 << accel_log2` consecutive match misses the scanner
    /// starts skipping bytes, so incompressible data costs ~O(n/step).
    fn accel_log2(self) -> u32 {
        match self {
            Algo::Raw => 0,
            Algo::Lz1 => 5,
            Algo::Lz2 => 7,
        }
    }
}

fn load4(src: &[u8], p: usize) -> u32 {
    u32::from_le_bytes([src[p], src[p + 1], src[p + 2], src[p + 3]])
}

fn hash4(v: u32, bits: u32) -> usize {
    (v.wrapping_mul(2_654_435_761) >> (32 - bits)) as usize
}

fn put_len_ext(out: &mut Vec<u8>, mut v: usize) {
    while v >= 255 {
        out.push(255);
        v -= 255;
    }
    out.push(v as u8);
}

fn emit_sequence(out: &mut Vec<u8>, literals: &[u8], offset: u16, mlen: usize) {
    let ll = literals.len();
    let ml = mlen - LZ_MIN_MATCH;
    out.push(((ll.min(15) as u8) << 4) | ml.min(15) as u8);
    if ll >= 15 {
        put_len_ext(out, ll - 15);
    }
    out.extend_from_slice(literals);
    out.extend_from_slice(&offset.to_le_bytes());
    if ml >= 15 {
        put_len_ext(out, ml - 15);
    }
}

fn emit_literal_run(out: &mut Vec<u8>, literals: &[u8]) {
    let ll = literals.len();
    out.push((ll.min(15) as u8) << 4);
    if ll >= 15 {
        put_len_ext(out, ll - 15);
    }
    out.extend_from_slice(literals);
}

/// Compress `src` into `out` (cleared first). `table` is the caller's
/// reusable hash-table scratch — [`FrameCodec`] owns one so the hot path
/// allocates nothing once warm. Output is never *read* by the encoder,
/// so compression cannot fail; it can only come out larger than the
/// input, which the caller's compress-if-beneficial guard handles.
pub fn lz_compress_into(src: &[u8], out: &mut Vec<u8>, table: &mut Vec<u32>, algo: Algo) {
    out.clear();
    let len = src.len();
    if algo == Algo::Raw || len < 16 {
        emit_literal_run(out, src);
        return;
    }
    let bits = algo.hash_bits();
    table.clear();
    table.resize(1usize << bits, u32::MAX);
    let accel = algo.accel_log2();
    let search_end = len - 8;
    let tail_end = len - LZ_TAIL;
    let mut anchor = 0usize;
    let mut pos = 0usize;
    let mut misses: u32 = 0;
    while pos < search_end {
        let here = load4(src, pos);
        let h = hash4(here, bits);
        let cand = table[h];
        table[h] = pos as u32;
        if cand != u32::MAX {
            let cand = cand as usize;
            if pos - cand <= LZ_MAX_OFFSET && load4(src, cand) == here {
                let mut mlen = LZ_MIN_MATCH;
                let max_m = tail_end - pos;
                while mlen < max_m && src[cand + mlen] == src[pos + mlen] {
                    mlen += 1;
                }
                emit_sequence(out, &src[anchor..pos], (pos - cand) as u16, mlen);
                pos += mlen;
                anchor = pos;
                misses = 0;
                continue;
            }
        }
        misses += 1;
        pos += 1 + (misses >> accel) as usize;
    }
    emit_literal_run(out, &src[anchor..]);
}

fn read_len_ext(src: &[u8], pos: &mut usize) -> anyhow::Result<usize> {
    let mut v = 0usize;
    loop {
        let b = *src
            .get(*pos)
            .ok_or_else(|| anyhow::anyhow!("codec: truncated length extension"))?;
        *pos += 1;
        v += b as usize;
        if b != 255 {
            return Ok(v);
        }
    }
}

/// Decompress `src` into `out` (cleared first), which must come out at
/// exactly `expected_len` bytes — the caller reads that from the frame
/// envelope *after* capping it, so a hostile stream can neither force an
/// allocation beyond the cap nor smuggle a short/long body through.
/// Never panics on any input.
pub fn lz_decompress_into(src: &[u8], out: &mut Vec<u8>, expected_len: usize) -> anyhow::Result<()> {
    out.clear();
    out.reserve(expected_len);
    let mut pos = 0usize;
    while pos < src.len() {
        let tok = src[pos];
        pos += 1;
        let mut ll = (tok >> 4) as usize;
        if ll == 15 {
            ll += read_len_ext(src, &mut pos)?;
        }
        anyhow::ensure!(pos + ll <= src.len(), "codec: truncated literal run");
        anyhow::ensure!(
            out.len() + ll <= expected_len,
            "codec: compressed body expands past its declared {expected_len} bytes"
        );
        out.extend_from_slice(&src[pos..pos + ll]);
        pos += ll;
        if pos == src.len() {
            break; // final sequence: literals only
        }
        anyhow::ensure!(pos + 2 <= src.len(), "codec: truncated match offset");
        let off = u16::from_le_bytes([src[pos], src[pos + 1]]) as usize;
        pos += 2;
        anyhow::ensure!(
            off >= 1 && off <= out.len(),
            "codec: match offset {off} out of range ({} bytes decoded)",
            out.len()
        );
        let mut ml = (tok & 0x0F) as usize + LZ_MIN_MATCH;
        if tok & 0x0F == 15 {
            ml += read_len_ext(src, &mut pos)?;
        }
        anyhow::ensure!(
            out.len() + ml <= expected_len,
            "codec: compressed body expands past its declared {expected_len} bytes"
        );
        // Overlapping back-reference: each pass doubles the available
        // run, so a RLE-style offset-1 match is O(log) passes.
        let start = out.len() - off;
        let mut remaining = ml;
        while remaining > 0 {
            let n = remaining.min(out.len() - start);
            out.extend_from_within(start..start + n);
            remaining -= n;
        }
    }
    anyhow::ensure!(
        out.len() == expected_len,
        "codec: decompressed {} bytes but the frame declared {expected_len}",
        out.len()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// configuration
// ---------------------------------------------------------------------------

/// Wire-compression mode (`--wire-compression`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireCompression {
    /// v1 frames, byte-for-byte: no packing, no byte compression.
    #[default]
    Off,
    /// Delta+varint packing of sparse/index frames only (cheap, always
    /// a win at sparse rates; dense bodies untouched).
    Delta,
    /// Delta packing plus the adaptive byte-compression pass.
    Full,
}

impl WireCompression {
    pub fn parse(s: &str) -> anyhow::Result<WireCompression> {
        match s {
            "off" | "none" => Ok(WireCompression::Off),
            "delta" | "index" => Ok(WireCompression::Delta),
            "full" | "on" => Ok(WireCompression::Full),
            other => anyhow::bail!(
                "unknown wire compression mode '{other}' (expected off | delta | full)"
            ),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            WireCompression::Off => "off",
            WireCompression::Delta => "delta",
            WireCompression::Full => "full",
        }
    }
}

/// Per-scheme algorithm override: `Auto` picks by body size, `Force`
/// pins one algorithm (`Force(Raw)` disables the byte pass for that
/// scheme while leaving delta packing on).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AlgoChoice {
    #[default]
    Auto,
    Force(Algo),
}

impl AlgoChoice {
    pub fn parse(s: &str) -> anyhow::Result<AlgoChoice> {
        match s {
            "auto" => Ok(AlgoChoice::Auto),
            other => Ok(AlgoChoice::Force(Algo::parse(other)?)),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            AlgoChoice::Auto => "auto",
            AlgoChoice::Force(a) => a.label(),
        }
    }
}

/// Env var consulted when `--wire-compression` is not given (strict
/// parse: set-but-invalid is a hard error, mirroring
/// `SCALECOM_SOCKET_TIMEOUT_SECS`).
pub const ENV_WIRE_COMPRESSION: &str = "SCALECOM_WIRE_COMPRESSION";

/// Read [`ENV_WIRE_COMPRESSION`]; `None` when unset.
pub fn env_wire_compression() -> anyhow::Result<Option<WireCompression>> {
    match std::env::var(ENV_WIRE_COMPRESSION) {
        Ok(s) => WireCompression::parse(s.trim())
            .map(Some)
            .map_err(|e| anyhow::anyhow!("{ENV_WIRE_COMPRESSION}={s}: {e}")),
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(e) => Err(anyhow::anyhow!("{ENV_WIRE_COMPRESSION}: {e}")),
    }
}

/// Bodies below this many bytes skip the byte-compression pass (the
/// wrapper overhead and timer cost would not pay for themselves).
pub const DEFAULT_MIN_COMPRESS_BYTES: usize = 1024;

/// Frame-codec configuration, threaded from config/CLI down to every
/// socket endpoint of a mesh. `Copy` on purpose: it rides inside
/// `LaneTransport`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireCodecConfig {
    pub mode: WireCompression,
    /// Minimum body size for the byte-compression pass.
    pub min_bytes: usize,
    /// Algorithm choice for dense ring chunks.
    pub dense: AlgoChoice,
    /// Algorithm choice for sparse gathers and index broadcasts.
    pub sparse: AlgoChoice,
}

impl Default for WireCodecConfig {
    fn default() -> Self {
        WireCodecConfig {
            mode: WireCompression::Off,
            min_bytes: DEFAULT_MIN_COMPRESS_BYTES,
            dense: AlgoChoice::Auto,
            sparse: AlgoChoice::Auto,
        }
    }
}

impl WireCodecConfig {
    /// v1 frames, byte-for-byte (the default).
    pub fn off() -> WireCodecConfig {
        WireCodecConfig::default()
    }

    pub fn with_mode(mode: WireCompression) -> WireCodecConfig {
        WireCodecConfig { mode, ..WireCodecConfig::default() }
    }

    /// Build from the CLI/config strings (`--wire-compression`,
    /// `--wire-compression-dense`, `--wire-compression-sparse`).
    pub fn from_strings(mode: &str, dense: &str, sparse: &str) -> anyhow::Result<WireCodecConfig> {
        Ok(WireCodecConfig {
            mode: WireCompression::parse(mode)?,
            min_bytes: DEFAULT_MIN_COMPRESS_BYTES,
            dense: AlgoChoice::parse(dense)?,
            sparse: AlgoChoice::parse(sparse)?,
        })
    }

    /// Does the encoder use the packed (v2) frame tags?
    pub fn packing(self) -> bool {
        self.mode != WireCompression::Off
    }

    /// Does the encoder run the byte-compression pass?
    pub fn byte_pass(self) -> bool {
        self.mode == WireCompression::Full
    }

    /// Minimum wire-codec version a peer must speak to decode our
    /// frames: packed tags need v2, `off` stays decodable by v1 peers.
    /// Deliberately *not* [`crate::comm::wire::WIRE_CODEC_VERSION`]: v3
    /// only added the liveness control frames, which compression never
    /// emits — a v2 peer decodes packed data frames fine (the heartbeat
    /// path enforces v3 separately at handshake).
    pub fn required_peer_codec(self) -> u8 {
        if self.packing() {
            2
        } else {
            1
        }
    }

    pub fn label(self) -> String {
        if self.byte_pass() {
            format!(
                "{} (dense={}, sparse={})",
                self.mode.label(),
                self.dense.label(),
                self.sparse.label()
            )
        } else {
            self.mode.label().to_string()
        }
    }
}

// ---------------------------------------------------------------------------
// per-algorithm stats
// ---------------------------------------------------------------------------

#[derive(Default)]
struct AlgoAtomics {
    enc_frames: AtomicU64,
    enc_raw_bytes: AtomicU64,
    enc_wire_bytes: AtomicU64,
    enc_ns: AtomicU64,
    dec_frames: AtomicU64,
    dec_wire_bytes: AtomicU64,
    dec_raw_bytes: AtomicU64,
    dec_ns: AtomicU64,
}

#[derive(Default)]
struct CodecAtomics {
    per_algo: [AlgoAtomics; Algo::COUNT],
    packed_frames: AtomicU64,
    guard_fallbacks: AtomicU64,
    sample_skips: AtomicU64,
}

/// Shared, cloneable codec counters: every [`FrameCodec`] of one lane
/// mesh (sender writer threads, receivers, all ranks of an in-process
/// ring) books into the same handle, and a snapshot rolls up into
/// `CommStats`.
#[derive(Clone, Default)]
pub struct CodecStats {
    inner: Arc<CodecAtomics>,
}

impl std::fmt::Debug for CodecStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.snapshot().fmt(f)
    }
}

impl CodecStats {
    pub fn new() -> CodecStats {
        CodecStats::default()
    }

    fn record_encode(&self, algo: Algo, raw_bytes: usize, wire_bytes: usize, ns: u64) {
        let a = &self.inner.per_algo[algo.index()];
        a.enc_frames.fetch_add(1, Ordering::Relaxed);
        a.enc_raw_bytes.fetch_add(raw_bytes as u64, Ordering::Relaxed);
        a.enc_wire_bytes.fetch_add(wire_bytes as u64, Ordering::Relaxed);
        a.enc_ns.fetch_add(ns, Ordering::Relaxed);
    }

    fn record_decode(&self, algo: Algo, wire_bytes: usize, raw_bytes: usize, ns: u64) {
        let a = &self.inner.per_algo[algo.index()];
        a.dec_frames.fetch_add(1, Ordering::Relaxed);
        a.dec_wire_bytes.fetch_add(wire_bytes as u64, Ordering::Relaxed);
        a.dec_raw_bytes.fetch_add(raw_bytes as u64, Ordering::Relaxed);
        a.dec_ns.fetch_add(ns, Ordering::Relaxed);
    }

    fn record_packed(&self) {
        self.inner.packed_frames.fetch_add(1, Ordering::Relaxed);
    }

    fn record_guard_fallback(&self) {
        self.inner.guard_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    fn record_sample_skip(&self) {
        self.inner.sample_skips.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CodecSnapshot {
        let mut s = CodecSnapshot::default();
        for (i, a) in self.inner.per_algo.iter().enumerate() {
            s.per_algo[i] = AlgoStats {
                enc_frames: a.enc_frames.load(Ordering::Relaxed),
                enc_raw_bytes: a.enc_raw_bytes.load(Ordering::Relaxed),
                enc_wire_bytes: a.enc_wire_bytes.load(Ordering::Relaxed),
                enc_ns: a.enc_ns.load(Ordering::Relaxed),
                dec_frames: a.dec_frames.load(Ordering::Relaxed),
                dec_wire_bytes: a.dec_wire_bytes.load(Ordering::Relaxed),
                dec_raw_bytes: a.dec_raw_bytes.load(Ordering::Relaxed),
                dec_ns: a.dec_ns.load(Ordering::Relaxed),
            };
        }
        s.packed_frames = self.inner.packed_frames.load(Ordering::Relaxed);
        s.guard_fallbacks = self.inner.guard_fallbacks.load(Ordering::Relaxed);
        s.sample_skips = self.inner.sample_skips.load(Ordering::Relaxed);
        s
    }
}

/// Counters for one algorithm. `raw` is the v1 (unpacked, uncompressed)
/// body size the same message would have cost, so `raw / wire` is the
/// end-to-end envelope ratio including delta packing.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AlgoStats {
    pub enc_frames: u64,
    pub enc_raw_bytes: u64,
    pub enc_wire_bytes: u64,
    pub enc_ns: u64,
    pub dec_frames: u64,
    pub dec_wire_bytes: u64,
    pub dec_raw_bytes: u64,
    pub dec_ns: u64,
}

/// Point-in-time roll-up of [`CodecStats`], surfaced through
/// `CommStats::codec`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CodecSnapshot {
    pub per_algo: [AlgoStats; Algo::COUNT],
    /// Frames that used a packed (delta+varint) representation.
    pub packed_frames: u64,
    /// Byte-pass attempts abandoned because the output was not smaller.
    pub guard_fallbacks: u64,
    /// Byte-pass attempts skipped by the high-entropy prefix probe.
    pub sample_skips: u64,
}

impl CodecSnapshot {
    pub fn algo(&self, a: Algo) -> &AlgoStats {
        &self.per_algo[a.index()]
    }

    pub fn enc_frames(&self) -> u64 {
        self.per_algo.iter().map(|a| a.enc_frames).sum()
    }

    pub fn enc_raw_bytes(&self) -> u64 {
        self.per_algo.iter().map(|a| a.enc_raw_bytes).sum()
    }

    pub fn enc_wire_bytes(&self) -> u64 {
        self.per_algo.iter().map(|a| a.enc_wire_bytes).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.per_algo.iter().all(|a| a.enc_frames == 0 && a.dec_frames == 0)
    }

    /// Envelope ratio: raw bytes the frames would have cost on a v1
    /// wire over bytes actually shipped (1.0 when nothing was saved).
    pub fn ratio(&self) -> f64 {
        let wire = self.enc_wire_bytes();
        if wire == 0 {
            return 1.0;
        }
        self.enc_raw_bytes() as f64 / wire as f64
    }

    /// One-line human summary for run reports.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        for a in Algo::ALL {
            let s = self.algo(a);
            if s.enc_frames > 0 {
                parts.push(format!(
                    "{}: {} frames {} -> {} B",
                    a.label(),
                    s.enc_frames,
                    s.enc_raw_bytes,
                    s.enc_wire_bytes
                ));
            }
        }
        format!(
            "codec {:.2}x ({}; packed {}, guard fallbacks {}, probe skips {})",
            self.ratio(),
            if parts.is_empty() { "idle".to_string() } else { parts.join(", ") },
            self.packed_frames,
            self.guard_fallbacks,
            self.sample_skips
        )
    }
}

// ---------------------------------------------------------------------------
// FrameCodec: per-endpoint policy + pooled scratch
// ---------------------------------------------------------------------------

/// Prefix length of the compressibility probe.
const SAMPLE_BYTES: usize = 4096;

/// One endpoint's frame encoder/decoder: owns the codec policy and all
/// scratch buffers (compression staging, probe, hash table), so the hot
/// path re-encodes multi-MB dense chunks with **zero** per-frame
/// allocation once the buffers are warm. Not `Sync` — each socket
/// writer thread / receiver owns its own, sharing only [`CodecStats`].
pub struct FrameCodec {
    cfg: WireCodecConfig,
    stats: CodecStats,
    /// Compressed-body staging (encode) / decompressed-body staging
    /// (decode).
    comp: Vec<u8>,
    /// Compressibility-probe output.
    sample: Vec<u8>,
    /// LZ hash table.
    table: Vec<u32>,
}

impl FrameCodec {
    pub fn new(cfg: WireCodecConfig, stats: CodecStats) -> FrameCodec {
        FrameCodec {
            cfg,
            stats,
            comp: Vec::new(),
            sample: Vec::new(),
            table: Vec::new(),
        }
    }

    pub fn cfg(&self) -> WireCodecConfig {
        self.cfg
    }

    pub fn stats(&self) -> &CodecStats {
        &self.stats
    }

    /// Encode one full frame (4-byte header + body) into `out`,
    /// reusing `out`'s capacity. Enforces the sender-side
    /// `MAX_FRAME_BYTES` cap like `wire::write_msg`.
    pub fn encode_frame_into(&mut self, msg: &crate::comm::wire::WireMsg, out: &mut Vec<u8>) -> anyhow::Result<()> {
        use crate::comm::wire;
        let t0 = std::time::Instant::now();
        let raw_body = wire::frame_len(msg) - 4;
        out.clear();
        out.extend_from_slice(&[0u8; 4]); // header patched below
        let packed = wire::encode_body_into(msg, self.cfg.packing(), out);
        if packed {
            self.stats.record_packed();
        }
        let mut algo = Algo::Raw;
        if self.cfg.byte_pass() {
            if let Some(a) = self.pick_algo(msg, out.len() - 4) {
                if self.try_compress_body(&out[4..], a) {
                    let inner_len = out.len() - 4;
                    out.truncate(4);
                    out.push(wire::TAG_COMPRESSED);
                    out.push(a.to_byte());
                    put_varint_u32(out, inner_len as u32);
                    out.extend_from_slice(&self.comp);
                    algo = a;
                }
            }
        }
        let body_len = out.len() - 4;
        anyhow::ensure!(
            body_len <= wire::MAX_FRAME_BYTES,
            "outgoing frame body of {body_len} bytes exceeds the {}-byte wire cap \
             (payload too large for one frame — lower the dimension or chunk it)",
            wire::MAX_FRAME_BYTES
        );
        out[..4].copy_from_slice(&(body_len as u32).to_le_bytes());
        self.stats
            .record_encode(algo, raw_body, body_len, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    /// Decode one frame body (bytes after the length header), staging
    /// any decompression through the pooled scratch.
    pub fn decode_body(&mut self, body: &[u8]) -> anyhow::Result<crate::comm::wire::WireMsg> {
        use crate::comm::wire;
        let t0 = std::time::Instant::now();
        let (algo, raw_len, msg) = if body.first() == Some(&wire::TAG_COMPRESSED) {
            let (algo, raw_len, payload) = wire::split_compressed(body)?;
            lz_decompress_into(payload, &mut self.comp, raw_len)?;
            (algo, raw_len, wire::decode_body_uncompressed(&self.comp)?)
        } else {
            (Algo::Raw, body.len(), wire::decode_body_uncompressed(body)?)
        };
        self.stats
            .record_decode(algo, body.len(), raw_len, t0.elapsed().as_nanos() as u64);
        Ok(msg)
    }

    /// Size-tiered algorithm selection (small bodies skip, mid bodies
    /// take the fast level, large ones the deeper level), respecting
    /// per-scheme overrides. The handshake is never compressed so a
    /// rendezvous stays parsable by any peer version.
    fn pick_algo(&self, msg: &crate::comm::wire::WireMsg, body_len: usize) -> Option<Algo> {
        use crate::comm::wire::WireMsg;
        if body_len < self.cfg.min_bytes {
            return None;
        }
        let choice = match msg {
            WireMsg::DenseChunk { .. }
            | WireMsg::DenseChunkLvl { .. }
            | WireMsg::JobChunk { .. } => self.cfg.dense,
            WireMsg::Sparse { .. } | WireMsg::Indices(_) | WireMsg::JobSparse { .. } => {
                self.cfg.sparse
            }
            // Handshake, liveness/recovery, and serve-protocol control
            // frames are tiny and latency-bound: always raw.
            WireMsg::Hello { .. }
            | WireMsg::Ping { .. }
            | WireMsg::Pong { .. }
            | WireMsg::Resume { .. }
            | WireMsg::SubmitJob { .. }
            | WireMsg::JobAccepted { .. }
            | WireMsg::JobRejected { .. }
            | WireMsg::JobProgress { .. }
            | WireMsg::JobDone { .. }
            | WireMsg::QueryStats { .. }
            | WireMsg::StatsReport { .. }
            | WireMsg::CancelJob { .. }
            | WireMsg::JobCancelled { .. } => return None,
        };
        match choice {
            AlgoChoice::Force(Algo::Raw) => None,
            AlgoChoice::Force(a) => Some(a),
            AlgoChoice::Auto => Some(if body_len <= 64 << 10 { Algo::Lz1 } else { Algo::Lz2 }),
        }
    }

    /// Run the byte pass into `self.comp`; `false` means ship raw
    /// (probe said high-entropy, or output was not smaller).
    fn try_compress_body(&mut self, body: &[u8], algo: Algo) -> bool {
        if body.len() > 4 * SAMPLE_BYTES {
            // Cheap probe: random f32 mantissas barely shrink — if a
            // prefix sample saves < 1/32, skip the full pass.
            lz_compress_into(&body[..SAMPLE_BYTES], &mut self.sample, &mut self.table, algo);
            if self.sample.len() >= SAMPLE_BYTES - SAMPLE_BYTES / 32 {
                self.stats.record_sample_skip();
                return false;
            }
        }
        lz_compress_into(body, &mut self.comp, &mut self.table, algo);
        let overhead = 2 + varint_len(body.len() as u32);
        if self.comp.len() + overhead >= body.len() {
            self.stats.record_guard_fallback();
            return false;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrips_across_widths() {
        let mut out = Vec::new();
        for v in [0u32, 1, 0x7F, 0x80, 0x3FFF, 0x4000, 0x1F_FFFF, 0x20_0000, u32::MAX] {
            out.clear();
            put_varint_u32(&mut out, v);
            assert_eq!(out.len(), varint_len(v), "v={v}");
            let mut pos = 0;
            assert_eq!(read_varint_u32(&out, &mut pos).unwrap(), v);
            assert_eq!(pos, out.len());
        }
    }

    #[test]
    fn varint_rejects_truncation_and_overflow() {
        let mut pos = 0;
        assert!(read_varint_u32(&[], &mut pos).is_err());
        let mut pos = 0;
        assert!(read_varint_u32(&[0x80], &mut pos).is_err(), "dangling continuation");
        // 5th byte carrying more than 4 significant bits overflows u32
        let mut pos = 0;
        assert!(read_varint_u32(&[0xFF, 0xFF, 0xFF, 0xFF, 0x10], &mut pos).is_err());
        // 6-byte encodings are rejected outright
        let mut pos = 0;
        assert!(read_varint_u32(&[0x80, 0x80, 0x80, 0x80, 0x80, 0x01], &mut pos).is_err());
    }

    #[test]
    fn index_deltas_roundtrip() {
        for idx in [
            vec![],
            vec![0u32],
            vec![u32::MAX],
            vec![0, 1, 2, 3],
            vec![5, 100, 10_000, 1_000_000, u32::MAX],
        ] {
            let mut out = Vec::new();
            put_index_deltas(&mut out, &idx);
            assert_eq!(out.len(), index_deltas_len(&idx));
            let mut pos = 0;
            assert_eq!(read_index_deltas(&out, &mut pos, idx.len()).unwrap(), idx);
            assert_eq!(pos, out.len());
        }
    }

    #[test]
    fn index_deltas_shrink_paper_like_index_sets() {
        // top-k at rate 112 over 1M: average gap ~112 → ≤ 2-byte varints
        let idx: Vec<u32> = (0..8928u32).map(|i| i * 112).collect();
        let packed = index_deltas_len(&idx);
        let raw = 4 * idx.len();
        assert!(
            packed * 2 <= raw,
            "delta+varint must at least halve paper-like index sets: {packed} vs {raw}"
        );
    }

    #[test]
    fn index_delta_overflow_rejected() {
        // deltas that accumulate past u32::MAX must error, not wrap
        let mut out = Vec::new();
        put_varint_u32(&mut out, u32::MAX); // first index
        put_varint_u32(&mut out, 10); // +11 overflows
        let mut pos = 0;
        assert!(read_index_deltas(&out, &mut pos, 2).is_err());
    }

    #[test]
    fn lz_roundtrips_structured_and_random_bodies() {
        let mut table = Vec::new();
        let mut comp = Vec::new();
        let mut back = Vec::new();
        let mut rng: u64 = 0x9E37_79B9_7F4A_7C15;
        let mut rand_byte = move || {
            rng ^= rng << 13;
            rng ^= rng >> 7;
            rng ^= rng << 17;
            (rng >> 32) as u8
        };
        let mut cases: Vec<Vec<u8>> = vec![
            vec![],
            b"a".to_vec(),
            b"abcdabcdabcdabcdabcdabcd".to_vec(),
            vec![0u8; 4096],
            [1u8, 2, 3, 4].repeat(2000),
            vec![b'x'; 15],
            vec![b'x'; 16],
            vec![b'x'; 17],
            (0..30000u32).map(|i| (i % 128) as u8).collect(),
        ];
        cases.push((0..5000).map(|_| rand_byte()).collect());
        cases.push((0..20000).map(|_| rand_byte() & 3).collect());
        for n in 0..40 {
            cases.push((0..n).map(|_| rand_byte()).collect());
        }
        for algo in [Algo::Lz1, Algo::Lz2] {
            for (i, c) in cases.iter().enumerate() {
                lz_compress_into(c, &mut comp, &mut table, algo);
                lz_decompress_into(&comp, &mut back, c.len())
                    .unwrap_or_else(|e| panic!("case {i} ({} B, {algo:?}): {e}", c.len()));
                assert_eq!(&back, c, "case {i} ({algo:?})");
            }
        }
    }

    #[test]
    fn lz_compresses_redundancy_well() {
        let mut table = Vec::new();
        let mut comp = Vec::new();
        lz_compress_into(&vec![0u8; 4096], &mut comp, &mut table, Algo::Lz1);
        assert!(comp.len() * 50 < 4096, "zeros must shrink >50x, got {}", comp.len());
    }

    #[test]
    fn lz_decompress_rejects_garbage_and_caps() {
        let mut out = Vec::new();
        let mut rng: u64 = 42;
        for _ in 0..2000 {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let n = (rng >> 33) as usize % 100;
            let garbage: Vec<u8> = (0..n)
                .map(|i| {
                    rng = rng.wrapping_mul(6364136223846793005).wrapping_add(i as u64);
                    (rng >> 40) as u8
                })
                .collect();
            // must never panic; wrong size / truncation / bad offsets error
            let _ = lz_decompress_into(&garbage, &mut out, (rng >> 20) as usize % 300);
        }
        // a valid stream must land on exactly the declared size
        let mut table = Vec::new();
        let mut comp = Vec::new();
        let body = [7u8; 1000];
        lz_compress_into(&body, &mut comp, &mut table, Algo::Lz1);
        assert!(lz_decompress_into(&comp, &mut out, 999).is_err(), "short declaration");
        assert!(lz_decompress_into(&comp, &mut out, 1001).is_err(), "long declaration");
        assert!(lz_decompress_into(&comp, &mut out, 1000).is_ok());
    }

    #[test]
    fn config_parsing() {
        assert_eq!(WireCompression::parse("off").unwrap(), WireCompression::Off);
        assert_eq!(WireCompression::parse("delta").unwrap(), WireCompression::Delta);
        assert_eq!(WireCompression::parse("full").unwrap(), WireCompression::Full);
        assert!(WireCompression::parse("gzip").is_err());
        assert_eq!(AlgoChoice::parse("auto").unwrap(), AlgoChoice::Auto);
        assert_eq!(AlgoChoice::parse("lz2").unwrap(), AlgoChoice::Force(Algo::Lz2));
        assert!(AlgoChoice::parse("zstd").is_err());
        let cfg = WireCodecConfig::from_strings("full", "raw", "lz1").unwrap();
        assert!(cfg.byte_pass());
        assert_eq!(cfg.dense, AlgoChoice::Force(Algo::Raw));
        assert_eq!(cfg.sparse, AlgoChoice::Force(Algo::Lz1));
        assert_eq!(WireCodecConfig::off().required_peer_codec(), 1);
        // pinned at 2: v3 added only control frames, so packed data
        // frames still interoperate with v2 peers
        assert_eq!(
            WireCodecConfig::with_mode(WireCompression::Delta).required_peer_codec(),
            2
        );
    }

    #[test]
    fn env_wire_compression_is_strict() {
        // NB: env vars are process-global; use a unique temp var via the
        // real one but restore it. Tests in this crate run threaded, so
        // only touch the var briefly and tolerate Unset races by using
        // set/remove around the asserts.
        std::env::set_var(ENV_WIRE_COMPRESSION, "delta");
        assert_eq!(env_wire_compression().unwrap(), Some(WireCompression::Delta));
        std::env::set_var(ENV_WIRE_COMPRESSION, "bogus");
        assert!(env_wire_compression().is_err(), "set-but-invalid must be loud");
        std::env::remove_var(ENV_WIRE_COMPRESSION);
        assert_eq!(env_wire_compression().unwrap(), None);
    }

    #[test]
    fn stats_roll_up_per_algorithm() {
        let stats = CodecStats::new();
        stats.record_encode(Algo::Raw, 100, 100, 50);
        stats.record_encode(Algo::Lz1, 1000, 250, 200);
        stats.record_decode(Algo::Lz1, 250, 1000, 180);
        stats.record_packed();
        stats.record_guard_fallback();
        let s = stats.snapshot();
        assert_eq!(s.enc_frames(), 2);
        assert_eq!(s.algo(Algo::Lz1).enc_wire_bytes, 250);
        assert_eq!(s.algo(Algo::Lz1).dec_raw_bytes, 1000);
        assert_eq!(s.enc_raw_bytes(), 1100);
        assert_eq!(s.enc_wire_bytes(), 350);
        assert!(s.ratio() > 3.0);
        assert_eq!(s.packed_frames, 1);
        assert_eq!(s.guard_fallbacks, 1);
        assert!(!s.is_empty());
        assert!(s.summary().contains("lz1"));
        // a clone shares the same counters
        let stats2 = stats.clone();
        stats2.record_encode(Algo::Lz2, 10, 10, 1);
        assert_eq!(stats.snapshot().enc_frames(), 3);
    }
}
