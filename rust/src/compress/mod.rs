//! Gradient sparsification: the paper's contribution (CLT-k + low-pass
//! filtered error-feedback memory) plus every baseline compressor it is
//! compared against in Table 1.
//!
//! Design: in fully-synchronous data-parallel training each step produces
//! one error-feedback gradient per worker (`m_i + ∇f_i`). A compression
//! *scheme* decides which coordinates each worker transmits. Commutative
//! schemes (Definition (1) in the paper) give every worker the *same*
//! index set, so sparse vectors can be **reduced** (added) by the fabric;
//! non-commutative schemes force a **gather**, which is the gradient
//! build-up problem of Figure 1(a).

pub mod chunk;
pub mod memory;
pub mod rate;
pub mod schemes;
pub mod sketch;

pub use chunk::{chunk_top1_indices, ChunkSelect};
pub use memory::EfMemory;
pub use rate::{rate_for_flops_ratio, LayerPartition};
pub use schemes::{make_compressor, CltK, GTopK, LocalTopK, RandomK, TrueTopK};

/// Sparse gradient: parallel arrays of (index, value), plus the dense
/// dimension. Indices are sorted and unique.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseGrad {
    pub dim: usize,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseGrad {
    pub fn new(dim: usize, indices: Vec<u32>, values: Vec<f32>) -> Self {
        debug_assert_eq!(indices.len(), values.len());
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices sorted+unique");
        debug_assert!(indices.last().map_or(true, |&i| (i as usize) < dim));
        SparseGrad {
            dim,
            indices,
            values,
        }
    }

    /// Extract `dense[indices]` as a sparse gradient.
    pub fn gather_from(dense: &[f32], indices: &[u32]) -> Self {
        let values = indices.iter().map(|&i| dense[i as usize]).collect();
        SparseGrad::new(dense.len(), indices.to_vec(), values)
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Wire size in bytes: 4-byte index + 4-byte value per nonzero.
    /// (The paper notes index traffic has the same degree of compression
    /// as values — §5 "Cost of index communication".)
    pub fn wire_bytes(&self) -> usize {
        self.nnz() * 8
    }

    /// Scatter into a dense vector (unset coordinates zero).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] = v;
        }
        out
    }

    /// Add into an accumulator dense vector.
    pub fn add_into(&self, acc: &mut [f32]) {
        assert_eq!(acc.len(), self.dim);
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            acc[i as usize] += v;
        }
    }

    /// Sum two sparse grads with identical index sets (the commutative
    /// reduce). Panics if index sets differ — that would silently be a
    /// gather, which callers must do explicitly.
    pub fn add_same_indices(&self, other: &SparseGrad) -> SparseGrad {
        assert_eq!(self.dim, other.dim);
        assert_eq!(
            self.indices, other.indices,
            "add_same_indices requires identical index sets (commutative reduce)"
        );
        let values = self
            .values
            .iter()
            .zip(&other.values)
            .map(|(a, b)| a + b)
            .collect();
        SparseGrad::new(self.dim, self.indices.clone(), values)
    }

    /// Union-merge (the gather path): index sets may differ; values at
    /// shared indices are summed. Complexity O(nnz_a + nnz_b).
    pub fn merge_add(&self, other: &SparseGrad) -> SparseGrad {
        assert_eq!(self.dim, other.dim);
        let mut indices = Vec::with_capacity(self.nnz() + other.nnz());
        let mut values = Vec::with_capacity(self.nnz() + other.nnz());
        let (mut i, mut j) = (0, 0);
        while i < self.nnz() || j < other.nnz() {
            let take_a = j >= other.nnz()
                || (i < self.nnz() && self.indices[i] <= other.indices[j]);
            let take_b = i >= self.nnz()
                || (j < other.nnz() && other.indices[j] <= self.indices[i]);
            if take_a && take_b {
                indices.push(self.indices[i]);
                values.push(self.values[i] + other.values[j]);
                i += 1;
                j += 1;
            } else if take_a {
                indices.push(self.indices[i]);
                values.push(self.values[i]);
                i += 1;
            } else {
                indices.push(other.indices[j]);
                values.push(other.values[j]);
                j += 1;
            }
        }
        SparseGrad::new(self.dim, indices, values)
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.values {
            *v *= s;
        }
    }
}

/// Per-step index selection produced by a compression scheme.
#[derive(Debug, Clone, PartialEq)]
pub enum Selection {
    /// All workers transmit the same coordinates → fabric can reduce.
    Shared(Vec<u32>),
    /// Each worker picked its own coordinates → fabric must gather.
    PerWorker(Vec<Vec<u32>>),
}

impl Selection {
    pub fn indices_for(&self, worker: usize) -> &[u32] {
        match self {
            Selection::Shared(ix) => ix,
            Selection::PerWorker(v) => &v[worker],
        }
    }

    pub fn is_shared(&self) -> bool {
        matches!(self, Selection::Shared(_))
    }
}

/// A gradient compression scheme (Table 1 row).
pub trait Compressor: Send {
    /// Human-readable name for logs/benches.
    fn name(&self) -> String;

    /// Decide which coordinates each worker transmits this step.
    ///
    /// `ef_grads[i]` is worker i's error-feedback gradient
    /// (`m_i^t + ∇̂f_i(θ^t)`), `k` the per-step budget. The in-process
    /// simulator exposes all workers' vectors; implementations must only
    /// look at what the real protocol could see (e.g. CLT-k reads only
    /// the cyclic leader's vector; local top-k only each worker's own).
    fn select(&mut self, step: usize, ef_grads: &[&[f32]], k: usize) -> Selection;

    /// Multi-threaded `select` used by the threaded backend. The contract
    /// is **identical output** — the backends are parity-locked — so the
    /// default just delegates; schemes whose ranking decomposes across
    /// spans (chunk scans, per-worker top-k) override it to fan the scan
    /// out over `threads` worker threads.
    fn select_parallel(
        &mut self,
        step: usize,
        ef_grads: &[&[f32]],
        k: usize,
        _threads: usize,
    ) -> Selection {
        self.select(step, ef_grads, k)
    }

    /// Commutative with averaging (Definition (1)): fabric may reduce.
    fn is_commutative(&self) -> bool;

    /// Approximate selection overhead in FLOPs per gradient element
    /// (Table 1 "overhead" column).
    fn overhead_flops_per_element(&self, dim: usize, k: usize) -> f64;
}

/// Compress a single worker's EF gradient with a chosen index set.
pub fn sparsify(ef_grad: &[f32], indices: &[u32]) -> SparseGrad {
    SparseGrad::gather_from(ef_grad, indices)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sg(dim: usize, ix: &[u32], vals: &[f32]) -> SparseGrad {
        SparseGrad::new(dim, ix.to_vec(), vals.to_vec())
    }

    #[test]
    fn gather_and_dense_roundtrip() {
        let dense = [0.5f32, -1.0, 0.0, 2.0];
        let s = SparseGrad::gather_from(&dense, &[1, 3]);
        assert_eq!(s.values, vec![-1.0, 2.0]);
        assert_eq!(s.to_dense(), vec![0.0, -1.0, 0.0, 2.0]);
        assert_eq!(s.wire_bytes(), 16);
    }

    #[test]
    fn add_same_indices_sums_values() {
        let a = sg(4, &[0, 2], &[1.0, 2.0]);
        let b = sg(4, &[0, 2], &[0.5, -1.0]);
        let c = a.add_same_indices(&b);
        assert_eq!(c.values, vec![1.5, 1.0]);
    }

    #[test]
    #[should_panic(expected = "identical index sets")]
    fn add_same_indices_rejects_mismatch() {
        let a = sg(4, &[0, 2], &[1.0, 2.0]);
        let b = sg(4, &[1, 2], &[0.5, -1.0]);
        let _ = a.add_same_indices(&b);
    }

    #[test]
    fn merge_add_unions() {
        let a = sg(6, &[0, 2, 5], &[1.0, 2.0, 3.0]);
        let b = sg(6, &[1, 2], &[10.0, -1.0]);
        let c = a.merge_add(&b);
        assert_eq!(c.indices, vec![0, 1, 2, 5]);
        assert_eq!(c.values, vec![1.0, 10.0, 1.0, 3.0]);
        // merge is symmetric
        assert_eq!(b.merge_add(&a), c);
    }

    #[test]
    fn merge_add_grows_toward_buildup() {
        // Disjoint index sets: nnz grows linearly — the Fig 1(a) effect.
        let a = sg(100, &[0, 1], &[1.0, 1.0]);
        let b = sg(100, &[50, 51], &[1.0, 1.0]);
        assert_eq!(a.merge_add(&b).nnz(), 4);
    }

    #[test]
    fn add_into_accumulates() {
        let a = sg(3, &[1], &[2.0]);
        let mut acc = vec![1.0f32; 3];
        a.add_into(&mut acc);
        assert_eq!(acc, vec![1.0, 3.0, 1.0]);
    }

    #[test]
    fn selection_accessors() {
        let s = Selection::Shared(vec![1, 2]);
        assert!(s.is_shared());
        assert_eq!(s.indices_for(7), &[1, 2]);
        let p = Selection::PerWorker(vec![vec![0], vec![3]]);
        assert!(!p.is_shared());
        assert_eq!(p.indices_for(1), &[3]);
    }

    #[test]
    fn scale_scales() {
        let mut a = sg(3, &[0, 1], &[2.0, 4.0]);
        a.scale(0.5);
        assert_eq!(a.values, vec![1.0, 2.0]);
    }
}
