//! Per-layer compression-rate guidance and layer partitioning.
//!
//! §4: "A conservative engineering guidance is proposed for compression
//! rate settings in each layer based upon the ratio FLOPs/gradient:
//! 25X for ratio in [196, ∞]; 50X for [128, 196), and 400X for (0, 128]"
//! (at reference per-worker mini-batch 32; the ratio scales linearly with
//! per-worker batch because FLOPs do and the gradient size does not).
//!
//! `LayerPartition` maps a flat parameter/gradient vector into named layer
//! slices so compression can run per layer with its own rate, exactly as
//! the paper applies it (and so the first conv layer can be exempted, per
//! Appendix E.1).

/// Compression rate from the FLOPs-per-gradient-element ratio. The bands
/// are stated at the reference per-worker batch of 32; callers scale the
/// ratio by `batch/32` before calling (see `LayerPartition::per_layer_k`).
pub fn rate_for_flops_ratio(flops_per_grad: f64) -> f64 {
    if flops_per_grad >= 196.0 {
        25.0
    } else if flops_per_grad >= 128.0 {
        50.0
    } else {
        400.0
    }
}

/// One layer's slice of the flat gradient.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSlice {
    pub name: String,
    pub offset: usize,
    pub len: usize,
    /// Forward FLOPs per sample for this layer (0 if unknown).
    pub flops_per_sample: f64,
    /// Layers marked uncompressed are sent dense (paper exempts the first
    /// conv layer: "very sensitive to compression").
    pub compress: bool,
}

/// Partition of a flat vector into layers.
#[derive(Debug, Clone, Default)]
pub struct LayerPartition {
    pub layers: Vec<LayerSlice>,
}

impl LayerPartition {
    /// Single pseudo-layer covering the whole vector.
    pub fn single(dim: usize) -> Self {
        LayerPartition {
            layers: vec![LayerSlice {
                name: "all".into(),
                offset: 0,
                len: dim,
                flops_per_sample: 0.0,
                compress: true,
            }],
        }
    }

    pub fn from_layers(layers: Vec<LayerSlice>) -> Self {
        let p = LayerPartition { layers };
        p.validate();
        p
    }

    /// Fallible construction — used by manifest loading, where malformed
    /// input must surface as an error rather than a panic.
    pub fn try_from_layers(layers: Vec<LayerSlice>) -> anyhow::Result<Self> {
        let p = LayerPartition { layers };
        p.check()?;
        Ok(p)
    }

    pub fn validate(&self) {
        if let Err(e) = self.check() {
            panic!("{e}");
        }
    }

    pub fn check(&self) -> anyhow::Result<()> {
        let mut expect = 0usize;
        for l in &self.layers {
            anyhow::ensure!(
                l.offset == expect,
                "layer '{}' offset {} != running total {}",
                l.name,
                l.offset,
                expect
            );
            anyhow::ensure!(l.len > 0, "layer '{}' empty", l.name);
            expect += l.len;
        }
        Ok(())
    }

    pub fn total_len(&self) -> usize {
        self.layers.iter().map(|l| l.len).sum()
    }

    /// Per-layer k for a target overall rate using the paper's guidance.
    /// If `use_flops_rule` and the layer has FLOPs info, its rate comes
    /// from `rate_for_flops_ratio`; otherwise `default_rate` applies.
    /// Uncompressed layers get k = len.
    pub fn per_layer_k(
        &self,
        default_rate: f64,
        per_worker_batch: usize,
        use_flops_rule: bool,
    ) -> Vec<usize> {
        self.layers
            .iter()
            .map(|l| {
                if !l.compress {
                    return l.len;
                }
                let rate = if use_flops_rule && l.flops_per_sample > 0.0 {
                    // bands defined at reference batch 32 (§4)
                    let ratio = l.flops_per_sample * (per_worker_batch as f64 / 32.0)
                        / l.len as f64;
                    rate_for_flops_ratio(ratio)
                } else {
                    default_rate
                };
                ((l.len as f64 / rate).ceil() as usize).clamp(1, l.len)
            })
            .collect()
    }

    /// Effective overall compression rate for a choice of per-layer k.
    pub fn effective_rate(&self, ks: &[usize]) -> f64 {
        let total: usize = self.total_len();
        let sent: usize = ks.iter().sum();
        total as f64 / sent as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guidance_bands_match_paper() {
        assert_eq!(rate_for_flops_ratio(500.0), 25.0);
        assert_eq!(rate_for_flops_ratio(196.0), 25.0);
        assert_eq!(rate_for_flops_ratio(195.9), 50.0);
        assert_eq!(rate_for_flops_ratio(128.0), 50.0);
        assert_eq!(rate_for_flops_ratio(127.9), 400.0);
        assert_eq!(rate_for_flops_ratio(1.0), 400.0);
    }

    #[test]
    fn single_partition_covers_all() {
        let p = LayerPartition::single(100);
        assert_eq!(p.total_len(), 100);
        let ks = p.per_layer_k(10.0, 32, false);
        assert_eq!(ks, vec![10]);
        assert_eq!(p.effective_rate(&ks), 10.0);
    }

    #[test]
    fn flops_rule_selects_band_per_layer() {
        // conv-like layer: many FLOPs per weight → gentle 25X
        // fc-like layer: 1 FLOP per weight per sample → aggressive 400X
        let p = LayerPartition::from_layers(vec![
            LayerSlice {
                name: "conv".into(),
                offset: 0,
                len: 1000,
                flops_per_sample: 500_000.0, // ratio 500000/1000 = 500 @ bsz 32
                compress: true,
            },
            LayerSlice {
                name: "fc".into(),
                offset: 1000,
                len: 4000,
                flops_per_sample: 4000.0, // ratio 1 @ bsz 32
                compress: true,
            },
        ]);
        let ks = p.per_layer_k(100.0, 32, true);
        assert_eq!(ks[0], 40); // 1000/25
        assert_eq!(ks[1], 10); // 4000/400

        // quadrupling the batch pushes the fc ratio to 4 (still 400X) and
        // the conv ratio to 2000 (still 25X) — but a layer at ratio 150
        // would move bands:
        let p2 = LayerPartition::from_layers(vec![LayerSlice {
            name: "mid".into(),
            offset: 0,
            len: 1000,
            flops_per_sample: 150_000.0, // ratio 150 @ 32 → 50X; 600 @ 128 → 25X
            compress: true,
        }]);
        assert_eq!(p2.per_layer_k(100.0, 32, true), vec![20]);
        assert_eq!(p2.per_layer_k(100.0, 128, true), vec![40]);
    }

    #[test]
    fn uncompressed_layer_sent_dense() {
        let p = LayerPartition::from_layers(vec![
            LayerSlice {
                name: "first_conv".into(),
                offset: 0,
                len: 64,
                flops_per_sample: 0.0,
                compress: false,
            },
            LayerSlice {
                name: "rest".into(),
                offset: 64,
                len: 936,
                flops_per_sample: 0.0,
                compress: true,
            },
        ]);
        let ks = p.per_layer_k(100.0, 32, false);
        assert_eq!(ks[0], 64);
        assert_eq!(ks[1], 10);
        let rate = p.effective_rate(&ks);
        assert!(rate > 10.0 && rate < 100.0);
    }

    #[test]
    fn k_at_least_one() {
        let p = LayerPartition::single(5);
        let ks = p.per_layer_k(400.0, 32, false);
        assert_eq!(ks, vec![1]);
    }

    #[test]
    #[should_panic(expected = "offset")]
    fn validate_rejects_gaps() {
        let _ = LayerPartition::from_layers(vec![
            LayerSlice {
                name: "a".into(),
                offset: 0,
                len: 10,
                flops_per_sample: 0.0,
                compress: true,
            },
            LayerSlice {
                name: "b".into(),
                offset: 20,
                len: 10,
                flops_per_sample: 0.0,
                compress: true,
            },
        ]);
    }
}
