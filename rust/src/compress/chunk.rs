//! Chunk-wise top-k selection — the paper's low-overhead "quasi-sort".
//!
//! §4: "We adopt [39] to accelerate sorting, which divides the whole
//! buffer into chunks and parallelizes sorting in each chunk", and
//! Table 1 credits ScaleCom with ~3 FLOPs/element (chunk-wise sort).
//! Appendix E's MNIST demo shows the concrete scheme: the buffer is cut
//! into chunks of `chunk_size` and the single largest-magnitude element
//! of each chunk is selected (`num_send=1` of each `chunk_size=4`).
//!
//! Selecting 1-of-C gives a compression rate of C (e.g. C=400 → 400×)
//! with exactly one |x| evaluation + one compare per element — O(1) per
//! element, no global sort. The same scheme is what the L1 Pallas kernel
//! (`python/compile/kernels/chunk_topk.py`) implements on-device; the two
//! are cross-checked in `rust/tests/kernel_parity.rs`.

/// Top-1-of-each-chunk selection. Returns sorted indices; the trailing
/// partial chunk (if any) also contributes one element.
///
/// Perf notes (EXPERIMENTS.md §Perf): the scan is branch-light — NaN is
/// excluded by IEEE `>` semantics (any comparison with NaN is false)
/// instead of a per-element `is_nan` branch, and `best_m` starts at -∞
/// so the first finite element always wins. Strict `>` keeps the lowest
/// index on ties — deterministic, matching `util::select` and the
/// Pallas kernel's argmax.
pub fn chunk_top1_indices(xs: &[f32], chunk_size: usize) -> Vec<u32> {
    assert!(chunk_size >= 1, "chunk_size must be >= 1");
    let n = xs.len();
    let mut out = Vec::with_capacity(n.div_ceil(chunk_size));
    let mut start = 0;
    while start < n {
        let end = (start + chunk_size).min(n);
        let mut best_i = start as u32;
        let mut best_m = f32::NEG_INFINITY;
        for (off, &x) in xs[start..end].iter().enumerate() {
            let m = x.abs();
            if m > best_m {
                best_m = m;
                best_i = (start + off) as u32;
            }
        }
        out.push(best_i);
        start = end;
    }
    out
}

/// Parallel `chunk_top1_indices`: fans the scan out over `threads` OS
/// threads on spans aligned to chunk boundaries, so each chunk is scanned
/// by exactly one thread and the concatenated result is **bit-identical**
/// to the sequential scan (chunk argmax is chunk-local). Small inputs
/// fall back to the sequential scan — thread spawn would dominate.
pub fn chunk_top1_indices_parallel(
    xs: &[f32],
    chunk_size: usize,
    threads: usize,
) -> Vec<u32> {
    assert!(chunk_size >= 1, "chunk_size must be >= 1");
    let n = xs.len();
    let total_chunks = n.div_ceil(chunk_size);
    if threads <= 1 || total_chunks < 2 * threads || n < (1 << 13) {
        return chunk_top1_indices(xs, chunk_size);
    }
    let span_elems = total_chunks.div_ceil(threads) * chunk_size;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                s.spawn(move || {
                    let lo = (t * span_elems).min(n);
                    let hi = ((t + 1) * span_elems).min(n);
                    if lo >= hi {
                        return Vec::new();
                    }
                    let mut ix = chunk_top1_indices(&xs[lo..hi], chunk_size);
                    for i in &mut ix {
                        *i += lo as u32;
                    }
                    ix
                })
            })
            .collect();
        let mut out = Vec::with_capacity(total_chunks);
        for h in handles {
            out.extend(h.join().expect("chunk-scan thread panicked"));
        }
        out
    })
}

/// Top-`per_chunk`-of-each-chunk generalization (the paper's demo uses
/// `num_send: 1`, larger values trade rate for fidelity).
pub fn chunk_topm_indices(xs: &[f32], chunk_size: usize, per_chunk: usize) -> Vec<u32> {
    assert!(per_chunk >= 1 && per_chunk <= chunk_size);
    if per_chunk == 1 {
        return chunk_top1_indices(xs, chunk_size);
    }
    let n = xs.len();
    let mut out = Vec::with_capacity(n.div_ceil(chunk_size) * per_chunk);
    let mut start = 0;
    while start < n {
        let end = (start + chunk_size).min(n);
        let m = per_chunk.min(end - start);
        let local = crate::util::select::top_k_indices_by_magnitude(&xs[start..end], m);
        out.extend(local.into_iter().map(|i| i + start as u32));
        start = end;
    }
    out
}

#[inline]
fn abs0(x: f32) -> f32 {
    let a = x.abs();
    if a.is_nan() {
        0.0
    } else {
        a
    }
}

/// Selection method used by a compressor when ranking a single worker's
/// vector: exact top-k or the chunked quasi-sort.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkSelect {
    /// Exact top-k via quickselect (O(n), higher constant).
    Exact,
    /// 1-of-C chunk max with a fixed chunk size, ~3 FLOPs/element.
    Chunked { chunk_size: usize },
    /// 1-of-C chunk max with C derived from the budget: C = ceil(len/k).
    /// This is what per-layer compression needs — each layer's chunks
    /// are sized so the layer yields its own k winners.
    ChunkedAuto,
}

impl ChunkSelect {
    /// Indices this method selects for budget `k` over `xs`.
    /// For fixed `Chunked`, `k` is advisory: the method returns one index
    /// per chunk (the caller sizes chunks so dim/chunk ≈ k).
    pub fn select(&self, xs: &[f32], k: usize) -> Vec<u32> {
        match *self {
            ChunkSelect::Exact => {
                crate::util::select::top_k_indices_by_magnitude(xs, k.min(xs.len()))
            }
            ChunkSelect::Chunked { chunk_size } => chunk_top1_indices(xs, chunk_size),
            ChunkSelect::ChunkedAuto => {
                let k = k.clamp(1, xs.len());
                chunk_top1_indices(xs, xs.len().div_ceil(k))
            }
        }
    }

    /// Multi-threaded `select` with identical output (the threaded
    /// backend's hot path). Both chunk variants are chunk-local and
    /// bit-identical under parallel scan; exact top-k merges per-span
    /// candidates with the same global tie-breaking rule.
    pub fn select_parallel(&self, xs: &[f32], k: usize, threads: usize) -> Vec<u32> {
        match *self {
            ChunkSelect::Exact => crate::util::select::top_k_indices_by_magnitude_parallel(
                xs,
                k.min(xs.len()),
                threads,
            ),
            ChunkSelect::Chunked { chunk_size } => {
                chunk_top1_indices_parallel(xs, chunk_size, threads)
            }
            ChunkSelect::ChunkedAuto => {
                let k = k.clamp(1, xs.len());
                chunk_top1_indices_parallel(xs, xs.len().div_ceil(k), threads)
            }
        }
    }

    /// Chunk size that realizes compression rate `rate` (1-of-C scheme).
    pub fn for_rate(rate: usize) -> ChunkSelect {
        ChunkSelect::Chunked {
            chunk_size: rate.max(1),
        }
    }

    pub fn k_for(&self, dim: usize, k: usize) -> usize {
        match *self {
            ChunkSelect::Exact => k.min(dim),
            ChunkSelect::Chunked { chunk_size } => dim.div_ceil(chunk_size),
            ChunkSelect::ChunkedAuto => {
                let k = k.clamp(1, dim);
                dim.div_ceil(dim.div_ceil(k))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_per_chunk_basic() {
        let xs = [1.0f32, -3.0, 2.0, 0.5, 0.1, -0.2, 9.0, 0.0];
        // chunks [0..4) and [4..8): max-mag are idx 1 (-3.0) and idx 6 (9.0)
        assert_eq!(chunk_top1_indices(&xs, 4), vec![1, 6]);
    }

    #[test]
    fn partial_trailing_chunk() {
        let xs = [1.0f32, 2.0, 3.0, 4.0, -5.0];
        assert_eq!(chunk_top1_indices(&xs, 2), vec![1, 3, 4]);
    }

    #[test]
    fn tie_prefers_lowest_index() {
        let xs = [2.0f32, -2.0, 1.0];
        assert_eq!(chunk_top1_indices(&xs, 3), vec![0]);
    }

    #[test]
    fn chunk_size_one_selects_all() {
        let xs = [1.0f32, 0.0, 3.0];
        assert_eq!(chunk_top1_indices(&xs, 1), vec![0, 1, 2]);
    }

    #[test]
    fn rate_matches_chunk_count() {
        let xs: Vec<f32> = (0..4000).map(|i| (i as f32 * 0.37).sin()).collect();
        let ix = chunk_top1_indices(&xs, 400);
        assert_eq!(ix.len(), 10); // 400x compression
        // each selected index is the argmax of its chunk
        for (c, &i) in ix.iter().enumerate() {
            let lo = c * 400;
            let hi = ((c + 1) * 400).min(xs.len());
            let best = (lo..hi).max_by(|&a, &b| {
                xs[a].abs().partial_cmp(&xs[b].abs()).unwrap()
                    .then(b.cmp(&a)) // prefer lower index
            }).unwrap();
            assert_eq!(i as usize, best);
        }
    }

    #[test]
    fn topm_generalizes_top1() {
        let xs = [5.0f32, 1.0, -4.0, 2.0, 0.0, 7.0, -6.0, 3.0];
        assert_eq!(chunk_topm_indices(&xs, 4, 1), chunk_top1_indices(&xs, 4));
        let two = chunk_topm_indices(&xs, 4, 2);
        assert_eq!(two, vec![0, 2, 5, 6]);
    }

    #[test]
    fn select_method_dispatch() {
        let xs = [1.0f32, -3.0, 2.0, 0.5];
        assert_eq!(ChunkSelect::Exact.select(&xs, 2), vec![1, 2]);
        assert_eq!(
            ChunkSelect::Chunked { chunk_size: 2 }.select(&xs, 0),
            vec![1, 2]
        );
        assert_eq!(ChunkSelect::for_rate(2), ChunkSelect::Chunked { chunk_size: 2 });
        assert_eq!(ChunkSelect::Exact.k_for(100, 7), 7);
        assert_eq!(ChunkSelect::Chunked { chunk_size: 10 }.k_for(100, 0), 10);
    }

    #[test]
    fn nan_never_selected_over_finite() {
        let xs = [f32::NAN, 1.0, f32::NAN, 0.5];
        assert_eq!(chunk_top1_indices(&xs, 4), vec![1]);
    }

    #[test]
    fn parallel_chunk_scan_bit_identical_to_sequential() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        for n in [0usize, 1, 399, 400, 401, 20_000, 100_003] {
            let xs: Vec<f32> = (0..n).map(|_| rng.next_normal_f32(0.0, 1.0)).collect();
            for chunk in [1usize, 3, 400] {
                for threads in [1usize, 2, 4, 7] {
                    assert_eq!(
                        chunk_top1_indices_parallel(&xs, chunk, threads),
                        chunk_top1_indices(&xs, chunk),
                        "n={n} chunk={chunk} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_dispatch_matches_select() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        let xs: Vec<f32> = (0..50_000).map(|_| rng.next_normal_f32(0.0, 1.0)).collect();
        for sel in [
            ChunkSelect::Exact,
            ChunkSelect::Chunked { chunk_size: 100 },
            ChunkSelect::ChunkedAuto,
        ] {
            assert_eq!(
                sel.select_parallel(&xs, 500, 4),
                sel.select(&xs, 500),
                "{sel:?}"
            );
        }
    }
}
