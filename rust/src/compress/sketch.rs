//! Count-sketch compressor (SketchSGD baseline, Ivkin et al. [24]).
//!
//! Sketches are *linear*: sketch(Σ x_i) = Σ sketch(x_i), so workers can
//! all-reduce their sketch tables (constant size, independent of n) and
//! recover approximate heavy hitters of the averaged gradient. Table 1
//! lists this as the other constant-scalability compressor; its overhead
//! is `2·H(·)·r` per element (r hash rows) and its achievable compression
//! (~40×) is lower than ScaleCom's because the sketch table plus a
//! second pass are needed.
//!
//! This implementation follows the paper's usage shape: estimate
//! magnitudes from a reduced sketch of the averaged EF gradient, take the
//! top-k estimates as the shared index set. (A real deployment does a
//! second exact pass over the chosen coordinates; our fabric charges that
//! cost in `comm::cost`.)

use crate::compress::{Compressor, Selection};

/// Count-sketch table: `rows` independent hash/sign pairs over `width`
/// buckets.
#[derive(Debug, Clone)]
pub struct CountSketch {
    pub rows: usize,
    pub width: usize,
    pub table: Vec<f32>, // rows * width
    seeds: Vec<u64>,
}

#[inline]
fn hash64(mut x: u64, seed: u64) -> u64 {
    // xxhash-style avalanche; good enough for bucket spreading.
    x ^= seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    x ^= x >> 33;
    x
}

impl CountSketch {
    pub fn new(rows: usize, width: usize, seed: u64) -> Self {
        assert!(rows >= 1 && width >= 2);
        CountSketch {
            rows,
            width,
            table: vec![0.0; rows * width],
            seeds: (0..rows as u64).map(|r| hash64(r + 1, seed)).collect(),
        }
    }

    #[inline]
    fn bucket_sign(&self, row: usize, i: u32) -> (usize, f32) {
        let h = hash64(i as u64, self.seeds[row]);
        let bucket = (h % self.width as u64) as usize;
        let sign = if (h >> 63) & 1 == 1 { 1.0 } else { -1.0 };
        (bucket, sign)
    }

    /// Accumulate a dense vector into the sketch.
    pub fn insert(&mut self, xs: &[f32]) {
        for row in 0..self.rows {
            let base = row * self.width;
            for (i, &x) in xs.iter().enumerate() {
                let (b, s) = self.bucket_sign(row, i as u32);
                self.table[base + b] += s * x;
            }
        }
    }

    /// Merge another sketch (linearity — the commutative reduce).
    pub fn merge(&mut self, other: &CountSketch) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.width, other.width);
        assert_eq!(self.seeds, other.seeds, "sketches must share hash seeds");
        for (a, b) in self.table.iter_mut().zip(&other.table) {
            *a += b;
        }
    }

    /// Median-of-rows point estimate of coordinate i.
    pub fn estimate(&self, i: u32) -> f32 {
        let mut ests: Vec<f32> = (0..self.rows)
            .map(|row| {
                let (b, s) = self.bucket_sign(row, i);
                s * self.table[row * self.width + b]
            })
            .collect();
        ests.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mid = self.rows / 2;
        if self.rows % 2 == 1 {
            ests[mid]
        } else {
            0.5 * (ests[mid - 1] + ests[mid])
        }
    }

    /// Wire size of the sketch table in bytes.
    pub fn wire_bytes(&self) -> usize {
        self.table.len() * 4
    }
}

/// SketchSGD-style compressor: sketch → (simulated) all-reduce of sketches
/// → top-k of the estimates as a shared index set.
pub struct SketchK {
    pub rows: usize,
    /// Sketch width as a fraction of the gradient dimension.
    pub width_frac: f64,
    pub seed: u64,
}

impl SketchK {
    pub fn default_for(seed: u64) -> Self {
        SketchK {
            rows: 5,
            width_frac: 0.02, // table ≈ 10% of dim → ~40x incl. 2nd pass
            seed,
        }
    }
}

impl Compressor for SketchK {
    fn name(&self) -> String {
        format!("sketch-k-r{}", self.rows)
    }

    fn select(&mut self, step: usize, ef_grads: &[&[f32]], k: usize) -> Selection {
        let dim = ef_grads[0].len();
        let width = ((dim as f64 * self.width_frac) as usize).max(k.max(4));
        // Per-step seed so bucket collisions differ across steps.
        let seed = hash64(step as u64 + 1, self.seed);
        let mut merged = CountSketch::new(self.rows, width, seed);
        for g in ef_grads {
            let mut s = CountSketch::new(self.rows, width, seed);
            s.insert(g);
            merged.merge(&s);
        }
        // Heavy hitters of the summed gradient by estimated magnitude.
        let estimates: Vec<f32> = (0..dim as u32).map(|i| merged.estimate(i)).collect();
        Selection::Shared(crate::util::select::top_k_indices_by_magnitude(
            &estimates,
            k.min(dim),
        ))
    }

    fn is_commutative(&self) -> bool {
        true
    }

    fn overhead_flops_per_element(&self, _dim: usize, _k: usize) -> f64 {
        // Table 1: 2 * H(.) * r — one hash+add per row on insert, and the
        // estimate pass costs the same again.
        2.0 * self.rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Selection;
    use crate::proptest::check;

    #[test]
    fn sketch_linearity() {
        // sketch(a) + sketch(b) == sketch(a + b) — the property that makes
        // sketches all-reducible.
        check("sketch linearity", 50, |g| {
            let dim = g.usize_in(4..=128);
            let a = g.f32_vec_len(dim, 1.0);
            let b = g.f32_vec_len(dim, 1.0);
            let mut sa = CountSketch::new(3, 16, 42);
            sa.insert(&a);
            let mut sb = CountSketch::new(3, 16, 42);
            sb.insert(&b);
            sa.merge(&sb);
            let sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
            let mut ss = CountSketch::new(3, 16, 42);
            ss.insert(&sum);
            for (x, y) in sa.table.iter().zip(&ss.table) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        });
    }

    #[test]
    fn heavy_hitter_recovered() {
        // One coordinate dominating the energy must be found.
        let mut xs = vec![0.01f32; 256];
        xs[97] = 50.0;
        let mut s = CountSketch::new(5, 64, 7);
        s.insert(&xs);
        let est = s.estimate(97);
        assert!((est - 50.0).abs() < 5.0, "estimate {est}");
        // and it beats everything else
        let best = (0..256u32)
            .max_by(|&a, &b| {
                s.estimate(a)
                    .abs()
                    .partial_cmp(&s.estimate(b).abs())
                    .unwrap()
            })
            .unwrap();
        assert_eq!(best, 97);
    }

    #[test]
    fn sketchk_selects_shared_heavy_hitters() {
        let mut g0 = vec![0.0f32; 512];
        let mut g1 = vec![0.0f32; 512];
        g0[10] = 30.0;
        g1[10] = 30.0;
        g0[200] = 20.0;
        g1[200] = 20.0;
        let views: Vec<&[f32]> = vec![&g0, &g1];
        // Wider table than the default so recovery is reliable at dim=512
        // (the default 2% width targets million-element gradients).
        let mut c = SketchK {
            rows: 5,
            width_frac: 0.25,
            seed: 3,
        };
        match c.select(0, &views, 2) {
            Selection::Shared(ix) => {
                assert!(ix.contains(&10), "{ix:?}");
                assert!(ix.contains(&200), "{ix:?}");
            }
            _ => panic!("sketch-k must be shared"),
        }
        assert!(c.is_commutative());
    }

    #[test]
    #[should_panic(expected = "share hash seeds")]
    fn merge_rejects_different_seeds() {
        let a = CountSketch::new(2, 8, 1);
        let mut b = CountSketch::new(2, 8, 2);
        b.merge(&a);
    }

    #[test]
    fn estimate_median_even_rows() {
        let mut s = CountSketch::new(2, 8, 9);
        s.insert(&[1.0, 2.0, 3.0]);
        // Just exercise the even-row median path.
        let _ = s.estimate(0);
        assert_eq!(s.wire_bytes(), 2 * 8 * 4);
    }
}
