//! Compression schemes: ScaleCom's CLT-k and every Table-1 baseline.

use crate::compress::chunk::ChunkSelect;
use crate::compress::{Compressor, Selection};
use crate::util::rng::Rng;
use crate::util::select::top_k_indices_by_magnitude;

/// Classical local top-k (Strom 2015 [21]): every worker independently
/// selects its own top-k. Not commutative — the fabric must gather, and
/// the reduced vector's nnz grows O(n) (gradient build-up, Fig 1a).
pub struct LocalTopK {
    pub select: ChunkSelect,
}

impl LocalTopK {
    pub fn new() -> Self {
        LocalTopK {
            select: ChunkSelect::Exact,
        }
    }
}

impl Default for LocalTopK {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for LocalTopK {
    fn name(&self) -> String {
        match self.select {
            ChunkSelect::Exact => "local-topk".into(),
            ChunkSelect::Chunked { chunk_size } => format!("local-topk-chunk{chunk_size}"),
            ChunkSelect::ChunkedAuto => "local-topk-chunked".into(),
        }
    }

    fn select(&mut self, _step: usize, ef_grads: &[&[f32]], k: usize) -> Selection {
        Selection::PerWorker(
            ef_grads
                .iter()
                .map(|g| self.select.select(g, k))
                .collect(),
        )
    }

    fn select_parallel(
        &mut self,
        _step: usize,
        ef_grads: &[&[f32]],
        k: usize,
        threads: usize,
    ) -> Selection {
        // Per-worker selections are independent; batch the workers so at
        // most `threads` OS threads run, preserving worker order.
        let n = ef_grads.len();
        if threads <= 1 || n <= 1 {
            return self.select(_step, ef_grads, k);
        }
        let method = self.select;
        let batch = n.div_ceil(threads.min(n));
        let per: Vec<Vec<u32>> = std::thread::scope(|s| {
            let handles: Vec<_> = ef_grads
                .chunks(batch)
                .map(|group| {
                    s.spawn(move || {
                        group
                            .iter()
                            .map(|&g| method.select(g, k))
                            .collect::<Vec<Vec<u32>>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("local top-k worker panicked"))
                .collect()
        });
        Selection::PerWorker(per)
    }

    fn is_commutative(&self) -> bool {
        false
    }

    fn overhead_flops_per_element(&self, dim: usize, _k: usize) -> f64 {
        match self.select {
            // full sort: O(log p) comparisons per element (Table 1 row 1)
            ChunkSelect::Exact => (dim as f64).log2(),
            ChunkSelect::Chunked { .. } | ChunkSelect::ChunkedAuto => 3.0,
        }
    }
}

/// ScaleCom's cyclic local top-k (Eqn. 3). The leader for step t is
/// `mod(t, n)`; its local top-k index set (computed with the chunk-wise
/// quasi-sort, ~3 FLOPs/element) is broadcast and used by all workers.
/// Commutative by construction: every worker sparsifies with the same set.
pub struct CltK {
    pub select: ChunkSelect,
}

impl CltK {
    /// Exact top-k leader selection (used in similarity studies).
    pub fn exact() -> Self {
        CltK {
            select: ChunkSelect::Exact,
        }
    }

    /// Paper-default chunk-wise selection: fixed chunk size == the
    /// compression rate (1-of-C). Matches the `<model>_compress` Pallas
    /// artifact, which is lowered with the same chunk constant.
    pub fn chunked(rate: usize) -> Self {
        CltK {
            select: ChunkSelect::for_rate(rate),
        }
    }

    /// Budget-derived chunk size (C = ceil(len/k)) — what per-layer
    /// compression needs, where each layer has its own k
    /// (`coordinator::select_layered`).
    pub fn chunked_auto() -> Self {
        CltK {
            select: ChunkSelect::ChunkedAuto,
        }
    }

    pub fn leader(step: usize, n: usize) -> usize {
        step % n
    }
}

impl Compressor for CltK {
    fn name(&self) -> String {
        match self.select {
            ChunkSelect::Exact => "scalecom-clt-k".into(),
            ChunkSelect::Chunked { chunk_size } => format!("scalecom-clt-k-chunk{chunk_size}"),
            ChunkSelect::ChunkedAuto => "scalecom-clt-k-chunked".into(),
        }
    }

    fn select(&mut self, step: usize, ef_grads: &[&[f32]], k: usize) -> Selection {
        let leader = Self::leader(step, ef_grads.len());
        Selection::Shared(self.select.select(ef_grads[leader], k))
    }

    fn select_parallel(
        &mut self,
        step: usize,
        ef_grads: &[&[f32]],
        k: usize,
        threads: usize,
    ) -> Selection {
        // Only the cyclic leader ranks; its chunk scan fans out across
        // the worker threads (bit-identical — chunks are scan-local).
        let leader = Self::leader(step, ef_grads.len());
        Selection::Shared(self.select.select_parallel(ef_grads[leader], k, threads))
    }

    fn is_commutative(&self) -> bool {
        true
    }

    fn overhead_flops_per_element(&self, dim: usize, _k: usize) -> f64 {
        match self.select {
            ChunkSelect::Exact => (dim as f64).log2(),
            // Table 1: ~3 (chunk-wise sort)
            ChunkSelect::Chunked { .. } | ChunkSelect::ChunkedAuto => 3.0,
        }
    }
}

/// Ideal "true top-k" (§2): top-k of the *averaged* error-feedback
/// gradient. Impractical (needs the dense average first — no compression
/// on the wire) but the contraction-property gold standard the paper
/// compares CLT-k against in Figs 2(b)/3.
pub struct TrueTopK;

impl Compressor for TrueTopK {
    fn name(&self) -> String {
        "true-topk".into()
    }

    fn select(&mut self, _step: usize, ef_grads: &[&[f32]], k: usize) -> Selection {
        let dim = ef_grads[0].len();
        let n = ef_grads.len() as f32;
        let mut avg = vec![0.0f32; dim];
        for g in ef_grads {
            for (a, &v) in avg.iter_mut().zip(g.iter()) {
                *a += v;
            }
        }
        for a in &mut avg {
            *a /= n;
        }
        Selection::Shared(top_k_indices_by_magnitude(&avg, k.min(dim)))
    }

    fn is_commutative(&self) -> bool {
        true
    }

    fn overhead_flops_per_element(&self, dim: usize, _k: usize) -> f64 {
        // dense average (n adds) + full sort
        (dim as f64).log2() + 1.0
    }
}

/// Random-k with a shared per-step seed: all workers draw the same k
/// random coordinates → commutative, but poor contraction (no energy
/// targeting). Included as the classic cheap baseline from [28].
pub struct RandomK {
    seed: u64,
}

impl RandomK {
    pub fn new(seed: u64) -> Self {
        RandomK { seed }
    }
}

impl Compressor for RandomK {
    fn name(&self) -> String {
        "random-k".into()
    }

    fn select(&mut self, step: usize, ef_grads: &[&[f32]], k: usize) -> Selection {
        let dim = ef_grads[0].len();
        let mut rng = Rng::for_stream(self.seed, step as u64);
        Selection::Shared(rng.sample_indices(dim, k.min(dim)))
    }

    fn is_commutative(&self) -> bool {
        true
    }

    fn overhead_flops_per_element(&self, _dim: usize, _k: usize) -> f64 {
        // selection cost independent of gradient content; ~k draws total
        0.1
    }
}

/// gTop-k (Shi et al. [27]): tree-style merge of the workers' local top-k
/// sparse vectors; at each of the ⌈log2 n⌉ rounds partner pairs exchange
/// their current sparse vectors, add them, and re-select top-k. The final
/// global winner set is broadcast. Approximates the top-k of the sum with
/// O(k log n) communication.
pub struct GTopK {
    pub select: ChunkSelect,
}

impl GTopK {
    pub fn new() -> Self {
        GTopK {
            select: ChunkSelect::Exact,
        }
    }

    /// Number of merge rounds for n workers.
    pub fn rounds(n: usize) -> usize {
        (usize::BITS - (n.max(1) - 1).leading_zeros()) as usize
    }
}

impl Default for GTopK {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for GTopK {
    fn name(&self) -> String {
        "gtop-k".into()
    }

    fn select(&mut self, _step: usize, ef_grads: &[&[f32]], k: usize) -> Selection {
        let n = ef_grads.len();
        let dim = ef_grads[0].len();
        let k = k.min(dim);
        // Each worker starts from its own local top-k sparse vector.
        let mut current: Vec<crate::compress::SparseGrad> = ef_grads
            .iter()
            .map(|g| {
                let idx = self.select.select(g, k);
                crate::compress::SparseGrad::gather_from(g, &idx)
            })
            .collect();
        // Binary-tree merge: stride doubles each round.
        let mut stride = 1;
        while stride < n {
            for i in (0..n).step_by(stride * 2) {
                let j = i + stride;
                if j < n {
                    let merged = current[i].merge_add(&current[j]);
                    // re-select top-k of the merged vector
                    let dense_vals = &merged.values;
                    let local =
                        top_k_indices_by_magnitude(dense_vals, k.min(dense_vals.len()));
                    let indices: Vec<u32> =
                        local.iter().map(|&p| merged.indices[p as usize]).collect();
                    let values: Vec<f32> =
                        local.iter().map(|&p| merged.values[p as usize]).collect();
                    let mut pairs: Vec<(u32, f32)> =
                        indices.into_iter().zip(values).collect();
                    pairs.sort_unstable_by_key(|&(i, _)| i);
                    current[i] = crate::compress::SparseGrad::new(
                        merged.dim,
                        pairs.iter().map(|&(i, _)| i).collect(),
                        pairs.iter().map(|&(_, v)| v).collect(),
                    );
                }
            }
            stride *= 2;
        }
        // Root (worker 0) holds the approximate global top-k set.
        Selection::Shared(current[0].indices.clone())
    }

    fn is_commutative(&self) -> bool {
        // The *final* set is shared, but selection requires log(n)
        // exchange rounds — Table 1 marks scalability O(log n).
        true
    }

    fn overhead_flops_per_element(&self, dim: usize, k: usize) -> f64 {
        // local sort + log n merge rounds over k-sized vectors
        (dim as f64).log2() + (k as f64 * 2.0) / dim as f64
    }
}

/// Construct a compressor by scheme name (CLI / config entry point).
pub fn make_compressor(
    scheme: &str,
    rate: usize,
    seed: u64,
) -> anyhow::Result<Box<dyn Compressor>> {
    Ok(match scheme {
        "scalecom" | "clt-k" => Box::new(CltK::chunked(rate)),
        "scalecom-auto" => Box::new(CltK::chunked_auto()),
        "scalecom-exact" | "clt-k-exact" => Box::new(CltK::exact()),
        "local-topk" => Box::new(LocalTopK::new()),
        "local-topk-chunk" => Box::new(LocalTopK {
            select: ChunkSelect::for_rate(rate),
        }),
        "true-topk" => Box::new(TrueTopK),
        "random-k" => Box::new(RandomK::new(seed)),
        "gtop-k" => Box::new(GTopK::new()),
        "sketch-k" => Box::new(crate::compress::sketch::SketchK::default_for(seed)),
        other => anyhow::bail!(
            "unknown compression scheme '{other}' \
             (expected scalecom|local-topk|true-topk|random-k|gtop-k|sketch-k)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::sparsify;
    use crate::proptest::check;

    fn views<'a>(vs: &'a [Vec<f32>]) -> Vec<&'a [f32]> {
        vs.iter().map(|v| v.as_slice()).collect()
    }

    #[test]
    fn clt_k_uses_cyclic_leader() {
        let g0 = vec![9.0f32, 0.1, 0.1, 0.1];
        let g1 = vec![0.1f32, 9.0, 0.1, 0.1];
        let grads = vec![g0, g1];
        let mut c = CltK::exact();
        // step 0 → leader 0 → index 0; step 1 → leader 1 → index 1
        assert_eq!(
            c.select(0, &views(&grads), 1),
            Selection::Shared(vec![0])
        );
        assert_eq!(
            c.select(1, &views(&grads), 1),
            Selection::Shared(vec![1])
        );
        assert_eq!(
            c.select(2, &views(&grads), 1),
            Selection::Shared(vec![0])
        );
        assert_eq!(CltK::leader(7, 3), 1);
    }

    #[test]
    fn clt_k_commutativity_property() {
        // sparse(avg(x_i)) == avg(sparse(x_i)) when all workers share the
        // leader's index set — Definition (1).
        check("CLT-k commutative", 100, |g| {
            let n = g.usize_in(2..=8);
            let dim = g.usize_in(4..=256);
            let k = g.usize_in(1..=dim);
            let grads: Vec<Vec<f32>> = (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
            let mut c = CltK::exact();
            let step = g.usize_in(0..=31);
            let sel = c.select(step, &views(&grads), k);
            let idx = match &sel {
                Selection::Shared(ix) => ix.clone(),
                _ => panic!("CLT-k must be shared"),
            };
            // avg then sparsify
            let mut avg = vec![0.0f32; dim];
            for w in &grads {
                for (a, &v) in avg.iter_mut().zip(w) {
                    *a += v / n as f32;
                }
            }
            let lhs = sparsify(&avg, &idx).to_dense();
            // sparsify then avg
            let mut rhs = vec![0.0f32; dim];
            for w in &grads {
                let s = sparsify(w, &idx);
                for (&i, &v) in s.indices.iter().zip(&s.values) {
                    rhs[i as usize] += v / n as f32;
                }
            }
            if let Err(i) = crate::util::floats::allclose(&lhs, &rhs, 1e-4, 1e-5) {
                panic!("commutativity violated at {i}: {} vs {}", lhs[i], rhs[i]);
            }
        });
    }

    #[test]
    fn local_topk_is_not_commutative_in_general() {
        // Different workers select different indices → averaging then
        // sparsifying differs from sparsifying then averaging.
        let g0 = vec![9.0f32, 0.0, 0.0, 1.0];
        let g1 = vec![0.0f32, 9.0, 0.0, 1.0];
        let grads = vec![g0, g1];
        let mut c = LocalTopK::new();
        let sel = c.select(0, &views(&grads), 1);
        match sel {
            Selection::PerWorker(ix) => {
                assert_eq!(ix[0], vec![0]);
                assert_eq!(ix[1], vec![1]);
            }
            _ => panic!("local top-k must be per-worker"),
        }
        assert!(!c.is_commutative());
    }

    #[test]
    fn true_topk_selects_top_of_average() {
        // coordinate 2 is strong in the average even though no worker has
        // it as its individual max.
        let g0 = vec![10.0f32, 0.0, 6.0];
        let g1 = vec![-10.0f32, 0.0, 6.0];
        let grads = vec![g0, g1];
        let mut c = TrueTopK;
        assert_eq!(c.select(0, &views(&grads), 1), Selection::Shared(vec![2]));
    }

    #[test]
    fn random_k_shared_and_step_dependent() {
        let grads = vec![vec![0.0f32; 64], vec![0.0f32; 64]];
        let mut c = RandomK::new(7);
        let s0 = c.select(0, &views(&grads), 8);
        let s0_again = c.select(0, &views(&grads), 8);
        let s1 = c.select(1, &views(&grads), 8);
        assert_eq!(s0, s0_again, "same step → same indices");
        assert_ne!(s0, s1, "different step → different indices");
        assert!(s0.is_shared());
    }

    #[test]
    fn gtopk_matches_true_topk_when_sets_overlap() {
        // If all workers agree on where the energy is, gTop-k must find
        // the exact global top-k.
        let g0 = vec![5.0f32, 4.0, 0.1, 0.1, 3.0, 0.1, 0.1, 0.1];
        let g1 = vec![5.0f32, 4.0, 0.1, 0.1, 3.0, 0.1, 0.1, 0.1];
        let g2 = vec![5.0f32, 4.0, 0.1, 0.1, 3.0, 0.1, 0.1, 0.1];
        let g3 = vec![5.0f32, 4.0, 0.1, 0.1, 3.0, 0.1, 0.1, 0.1];
        let grads = vec![g0, g1, g2, g3];
        let mut c = GTopK::new();
        assert_eq!(
            c.select(0, &views(&grads), 3),
            Selection::Shared(vec![0, 1, 4])
        );
        assert_eq!(GTopK::rounds(4), 2);
        assert_eq!(GTopK::rounds(5), 3);
        assert_eq!(GTopK::rounds(1), 0);
    }

    #[test]
    fn gtopk_selection_size_bounded_by_k() {
        check("gtopk |S| <= k", 50, |g| {
            let n = g.usize_in(2..=8);
            let dim = g.usize_in(8..=128);
            let k = g.usize_in(1..=dim / 2);
            let grads: Vec<Vec<f32>> = (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
            let mut c = GTopK::new();
            match c.select(0, &views(&grads), k) {
                Selection::Shared(ix) => {
                    assert!(ix.len() <= k);
                    assert!(ix.windows(2).all(|w| w[0] < w[1]));
                }
                _ => panic!(),
            }
        });
    }

    #[test]
    fn factory_constructs_all_schemes() {
        for s in [
            "scalecom",
            "scalecom-exact",
            "local-topk",
            "local-topk-chunk",
            "true-topk",
            "random-k",
            "gtop-k",
            "sketch-k",
        ] {
            let c = make_compressor(s, 100, 1).unwrap();
            assert!(!c.name().is_empty());
        }
        assert!(make_compressor("nope", 100, 1).is_err());
    }

    #[test]
    fn overhead_table1_shape() {
        // Table 1: CLT-k chunked ≈ 3 FLOPs/elem, top-k ≈ log p.
        let clt = CltK::chunked(400);
        assert_eq!(clt.overhead_flops_per_element(1 << 20, 1000), 3.0);
        let topk = LocalTopK::new();
        assert!((topk.overhead_flops_per_element(1 << 20, 1000) - 20.0).abs() < 1e-9);
    }
}
