//! Error-feedback ("local") memory with the paper's low-pass filter.
//!
//! Algorithm 1 lines 6–7:
//!   g_i^t    = CLT_{mod(t,n)}^k (m_i^t + ∇̂f_i(θ^t))
//!   m_i^{t+1} = (1-β) m_i^t + β (m_i^t + ∇̂f_i(θ^t) − g_i^t)
//!
//! Because g_i equals (m_i + grad) exactly on the selected coordinates and
//! 0 elsewhere, the update simplifies coordinate-wise to
//!   selected:    m' = (1-β) · m           (sent energy leaves the memory)
//!   unselected:  m' = m + β · grad        (incoming residue is low-passed)
//! which is what `update_after_send` implements in a single O(p) pass.
//! β=1 recovers classical error feedback (memory zeroed where sent).

use crate::compress::SparseGrad;

/// Per-worker error-feedback memory.
#[derive(Debug, Clone)]
pub struct EfMemory {
    m: Vec<f32>,
    beta: f32,
}

impl EfMemory {
    pub fn new(dim: usize, beta: f32) -> Self {
        assert!(
            beta > 0.0 && beta <= 1.0,
            "discount factor β must be in (0, 1], got {beta}"
        );
        EfMemory {
            m: vec![0.0; dim],
            beta,
        }
    }

    pub fn dim(&self) -> usize {
        self.m.len()
    }

    pub fn beta(&self) -> f32 {
        self.beta
    }

    /// Change β mid-training (Appendix E.2 raises β back to 1 at epoch 60
    /// for ResNet50 once the LR has decayed).
    pub fn set_beta(&mut self, beta: f32) {
        assert!(beta > 0.0 && beta <= 1.0);
        self.beta = beta;
    }

    pub fn memory(&self) -> &[f32] {
        &self.m
    }

    /// Error-feedback gradient `m_i^t + grad` (Algorithm 1 line 6 input).
    pub fn ef_grad(&self, grad: &[f32]) -> Vec<f32> {
        assert_eq!(grad.len(), self.m.len());
        self.m.iter().zip(grad).map(|(m, g)| m + g).collect()
    }

    /// Error-feedback gradient restricted to one contiguous slice of the
    /// flat vector: `m[offset..offset+grad.len()] + grad`. The math is
    /// coordinate-wise, so this is bit-identical to the matching slice of
    /// [`EfMemory::ef_grad`] — the bucketed exchange depends on that.
    pub fn ef_grad_range(&self, offset: usize, grad: &[f32]) -> Vec<f32> {
        assert!(
            offset + grad.len() <= self.m.len(),
            "ef_grad_range [{offset}, {}) out of bounds for dim {}",
            offset + grad.len(),
            self.m.len()
        );
        self.m[offset..offset + grad.len()]
            .iter()
            .zip(grad)
            .map(|(m, g)| m + g)
            .collect()
    }

    /// Apply the low-pass memory update after `indices` were transmitted.
    /// `grad` is this step's computed stochastic gradient.
    pub fn update_after_send(&mut self, grad: &[f32], sent_indices: &[u32]) {
        assert_eq!(grad.len(), self.m.len());
        self.update_after_send_range(0, grad, sent_indices);
    }

    /// The low-pass update restricted to one contiguous slice (a bucket):
    /// `grad` covers `[offset, offset + grad.len())` and `sent_local`
    /// holds slice-relative indices. Disjoint slices commute, and each
    /// slice's math is bit-identical to the matching span of the
    /// full-vector [`EfMemory::update_after_send`] — so a bucketed step
    /// (one call per bucket, any order) leaves exactly the memory a
    /// monolithic step would.
    pub fn update_after_send_range(&mut self, offset: usize, grad: &[f32], sent_local: &[u32]) {
        assert!(
            offset + grad.len() <= self.m.len(),
            "update range [{offset}, {}) out of bounds for dim {}",
            offset + grad.len(),
            self.m.len()
        );
        let beta = self.beta;
        let m = &mut self.m[offset..offset + grad.len()];
        // Pass 1: unselected update for every coordinate...
        for (mi, &g) in m.iter_mut().zip(grad) {
            *mi += beta * g;
        }
        // Pass 2: ...then overwrite the selected ones with (1-β)·m_old.
        // (m_old = m_new − β·g on those coordinates.)
        for &i in sent_local {
            let i = i as usize;
            let m_old = m[i] - beta * grad[i];
            m[i] = (1.0 - beta) * m_old;
        }
    }

    /// Reference (textbook) update used by tests: materializes g_i^t and
    /// applies Eqn. (5) literally.
    pub fn update_reference(&mut self, grad: &[f32], sent: &SparseGrad) {
        let beta = self.beta;
        let g_dense = sent.to_dense();
        for i in 0..self.m.len() {
            let residue = self.m[i] + grad[i] - g_dense[i];
            self.m[i] = (1.0 - beta) * self.m[i] + beta * residue;
        }
    }

    /// Replace the memory wholesale — used by the L1-kernel path, where
    /// the Pallas `lowpass` artifact computes m^{t+1} on-device.
    pub fn set_memory(&mut self, m: Vec<f32>) {
        assert_eq!(m.len(), self.m.len(), "set_memory dim mismatch");
        self.m = m;
    }

    /// Total residual energy ‖m‖₂ — logged for Fig 2-style diagnostics.
    pub fn norm(&self) -> f64 {
        crate::util::floats::l2_norm(&self.m)
    }

    /// Reset (used between experiments / at compression warmup start).
    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::sparsify;
    use crate::proptest::check;
    use crate::util::floats::allclose;

    #[test]
    fn beta_one_is_classic_error_feedback() {
        let mut mem = EfMemory::new(4, 1.0);
        let grad = [1.0f32, -2.0, 3.0, 0.5];
        let ef = mem.ef_grad(&grad);
        assert_eq!(ef, grad.to_vec()); // memory starts at 0
        mem.update_after_send(&grad, &[2]); // send coordinate 2
        assert_eq!(mem.memory(), &[1.0, -2.0, 0.0, 0.5]);
    }

    #[test]
    fn fast_update_matches_reference_formula() {
        check("lowpass fast == Eqn.(5)", 150, |g| {
            let dim = g.usize_in(1..=256);
            let beta = g.f32_in(0.05, 1.0);
            let grad = g.f32_vec_len(dim, 1.0);
            let prev = g.f32_vec_len(dim, 0.5);
            let k = g.usize_in(0..=dim);
            let mut fast = EfMemory::new(dim, beta);
            fast.m.copy_from_slice(&prev);
            let mut refr = fast.clone();

            let ef = fast.ef_grad(&grad);
            let idx = crate::util::select::top_k_indices_by_magnitude(&ef, k);
            let sent = sparsify(&ef, &idx);

            fast.update_after_send(&grad, &idx);
            refr.update_reference(&grad, &sent);
            if let Err(i) = allclose(fast.memory(), refr.memory(), 1e-5, 1e-5) {
                panic!(
                    "mismatch at {i}: fast={} ref={} (beta={beta})",
                    fast.memory()[i],
                    refr.memory()[i]
                );
            }
        });
    }

    #[test]
    fn conservation_with_beta_one() {
        // With β=1: m' + g_sent == m + grad (no energy lost or created).
        check("EF conservation β=1", 100, |g| {
            let dim = g.usize_in(1..=128);
            let grad = g.f32_vec_len(dim, 1.0);
            let mut mem = EfMemory::new(dim, 1.0);
            mem.m.copy_from_slice(&g.f32_vec_len(dim, 1.0));
            let before: Vec<f32> = mem.ef_grad(&grad);
            let k = g.usize_in(0..=dim);
            let idx = crate::util::select::top_k_indices_by_magnitude(&before, k);
            let sent = sparsify(&before, &idx);
            mem.update_after_send(&grad, &idx);
            let mut reconstructed = sent.to_dense();
            for (r, m) in reconstructed.iter_mut().zip(mem.memory()) {
                *r += m;
            }
            if let Err(i) = allclose(&reconstructed, &before, 1e-5, 1e-5) {
                panic!("conservation broken at {i}");
            }
        });
    }

    #[test]
    fn low_pass_attenuates_unsent_noise() {
        // β<1 must shrink how much of an incoming residue enters memory.
        let grad = [10.0f32, 0.0];
        let mut m_small_beta = EfMemory::new(2, 0.1);
        let mut m_beta_one = EfMemory::new(2, 1.0);
        // send nothing: residue = grad
        m_small_beta.update_after_send(&grad, &[]);
        m_beta_one.update_after_send(&grad, &[]);
        assert!((m_small_beta.memory()[0] - 1.0).abs() < 1e-6);
        assert!((m_beta_one.memory()[0] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn sent_coordinates_decay_not_zero_when_beta_lt_one() {
        let mut mem = EfMemory::new(1, 0.25);
        mem.m[0] = 4.0;
        let grad = [1.0f32];
        // ef = 5.0, send it
        mem.update_after_send(&grad, &[0]);
        // m' = (1-β)·m_old = 0.75·4 = 3.0
        assert!((mem.memory()[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn range_ops_tile_to_the_full_vector_bit_exactly() {
        // Splitting the vector into arbitrary contiguous slices and
        // applying the range ops per slice must be bit-identical to the
        // full-vector ops — the bucketed-exchange determinism contract.
        check("EF range ops == full-vector ops", 80, |g| {
            let dim = g.usize_in(1..=128);
            let beta = g.f32_in(0.05, 1.0);
            let grad = g.f32_vec_len(dim, 1.0);
            let prev = g.f32_vec_len(dim, 0.5);
            let mut full = EfMemory::new(dim, beta);
            full.m.copy_from_slice(&prev);
            let mut split = full.clone();
            // random contiguous slicing
            let mut cuts: Vec<usize> = (0..g.usize_in(0..=4)).map(|_| g.usize_in(0..=dim)).collect();
            cuts.push(0);
            cuts.push(dim);
            cuts.sort_unstable();
            cuts.dedup();
            // one global selection, split per slice
            let ef = full.ef_grad(&grad);
            let k = g.usize_in(0..=dim);
            let idx = crate::util::select::top_k_indices_by_magnitude(&ef, k);
            full.update_after_send(&grad, &idx);
            for w in cuts.windows(2) {
                let (lo, hi) = (w[0], w[1]);
                let local: Vec<u32> = idx
                    .iter()
                    .filter(|&&i| (i as usize) >= lo && (i as usize) < hi)
                    .map(|&i| i - lo as u32)
                    .collect();
                // range EF read matches the full read on this span
                assert_eq!(split.ef_grad_range(lo, &grad[lo..hi]), ef[lo..hi].to_vec());
                split.update_after_send_range(lo, &grad[lo..hi], &local);
            }
            assert_eq!(full.memory(), split.memory(), "range tiling must be exact");
        });
    }

    #[test]
    #[should_panic(expected = "discount factor")]
    fn rejects_bad_beta() {
        let _ = EfMemory::new(4, 0.0);
    }

    #[test]
    fn set_beta_and_reset() {
        let mut mem = EfMemory::new(2, 0.1);
        mem.set_beta(1.0);
        assert_eq!(mem.beta(), 1.0);
        mem.update_after_send(&[1.0, 2.0], &[]);
        assert!(mem.norm() > 0.0);
        mem.reset();
        assert_eq!(mem.norm(), 0.0);
    }
}
