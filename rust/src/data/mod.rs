//! Synthetic datasets standing in for the paper's corpora.
//!
//! Substitution map (see DESIGN.md §4): ImageNet/CIFAR10 → gaussian
//! cluster classification; WMT14 En-De → a synthetic character-level
//! corpus with Markov structure (so a language model has real signal to
//! learn); SWB300 speech → smooth multi-sine sequences with frame labels
//! (so a recurrent model must integrate temporal context).
//!
//! All generators are deterministic in `(seed)` and support worker
//! sharding identical to the paper's fully-synchronized data-parallel
//! setup: shard i of n sees sample indices ≡ i (mod n).

pub mod lm;
pub mod sequence;
pub mod vectors;

pub use lm::LmCorpus;
pub use sequence::SequenceDataset;
pub use vectors::{ClusterDataset, ImagePatternDataset};

/// A mini-batch of flat features + integer labels.
#[derive(Debug, Clone)]
pub struct Batch {
    /// row-major [batch, feature_dim]
    pub x: Vec<f32>,
    /// [batch] class ids (or [batch*seq] for sequence tasks)
    pub y: Vec<i32>,
    pub batch: usize,
    pub feature_dim: usize,
}

impl Batch {
    pub fn validate(&self) {
        assert_eq!(self.x.len(), self.batch * self.feature_dim);
        assert!(self.y.len() % self.batch == 0);
    }
}

/// Common interface: deterministic batch for (worker, step).
pub trait Dataset: Send + Sync {
    /// Distinct deterministic batch per (worker, step) pair; workers
    /// always draw disjoint shards for the same step.
    fn batch(&self, worker: usize, n_workers: usize, step: usize, batch_size: usize) -> Batch;

    /// Held-out evaluation batch (same for all callers).
    fn eval_batch(&self, batch_size: usize) -> Batch;

    fn feature_dim(&self) -> usize;
    fn num_classes(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_dataset(ds: &dyn Dataset) {
        let b = ds.batch(0, 4, 0, 8);
        b.validate();
        assert_eq!(b.batch, 8);
        assert_eq!(b.feature_dim, ds.feature_dim());
        // determinism
        let b2 = ds.batch(0, 4, 0, 8);
        assert_eq!(b.x, b2.x);
        assert_eq!(b.y, b2.y);
        // different worker → different shard
        let b3 = ds.batch(1, 4, 0, 8);
        assert_ne!(b.x, b3.x);
        // different step → different data
        let b4 = ds.batch(0, 4, 1, 8);
        assert_ne!(b.x, b4.x);
        // labels in range
        for &y in &b.y {
            assert!(y >= 0 && (y as usize) < ds.num_classes());
        }
        let e = ds.eval_batch(16);
        e.validate();
    }

    #[test]
    fn all_datasets_satisfy_contract() {
        check_dataset(&ClusterDataset::new(16, 10, 1234));
        check_dataset(&ImagePatternDataset::new(8, 5, 1234));
        check_dataset(&LmCorpus::new(32, 16, 1234));
        check_dataset(&SequenceDataset::new(8, 12, 6, 1234));
    }
}
