//! Smooth multi-sine sequences with frame labels (speech stand-in).
//!
//! Each sample is a `seq`-frame window of a multi-tone signal whose
//! "phoneme" label per frame is the identity of the dominant tone —
//! the label depends on temporal context (phase), so a recurrent model
//! (our LSTM-lite) genuinely benefits from integrating over time, like
//! an acoustic model does.

use crate::data::{Batch, Dataset};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SequenceDataset {
    pub feat: usize,
    pub seq: usize,
    pub classes: usize,
    seed: u64,
    /// per-class tone frequencies (radians/frame) for each feature dim
    freqs: Vec<Vec<f32>>,
}

impl SequenceDataset {
    pub fn new(feat: usize, seq: usize, classes: usize, seed: u64) -> Self {
        let mut rng = Rng::for_stream(seed, 0x5E9);
        let freqs = (0..classes)
            .map(|_| {
                (0..feat)
                    .map(|_| 0.2 + 1.2 * rng.next_f32())
                    .collect::<Vec<f32>>()
            })
            .collect();
        SequenceDataset {
            feat,
            seq,
            classes,
            seed,
            freqs,
        }
    }

    fn make_batch(&self, rng: &mut Rng, batch_size: usize) -> Batch {
        // features: [batch, seq*feat] flattened frames
        let mut x = Vec::with_capacity(batch_size * self.seq * self.feat);
        let mut y = Vec::with_capacity(batch_size * self.seq);
        for _ in 0..batch_size {
            // piecewise-constant class sequence: segments of 3–6 frames
            let mut t = 0usize;
            let phase0 = rng.next_f32() * 6.28;
            while t < self.seq {
                let c = rng.next_below(self.classes as u64) as usize;
                let seg = 3 + rng.next_below(4) as usize;
                for _ in 0..seg.min(self.seq - t) {
                    for f in 0..self.feat {
                        let w = self.freqs[c][f];
                        let v = (phase0 + w * t as f32).sin()
                            + 0.1 * rng.next_normal_f32(0.0, 1.0);
                        x.push(v);
                    }
                    y.push(c as i32);
                    t += 1;
                }
            }
        }
        Batch {
            x,
            y,
            batch: batch_size,
            feature_dim: self.seq * self.feat,
        }
    }
}

impl Dataset for SequenceDataset {
    fn batch(&self, worker: usize, n_workers: usize, step: usize, batch_size: usize) -> Batch {
        assert!(worker < n_workers);
        let stream = (step as u64) * (n_workers as u64) + worker as u64 + 1;
        let mut rng = Rng::for_stream(self.seed ^ 0x5EC, stream);
        self.make_batch(&mut rng, batch_size)
    }

    fn eval_batch(&self, batch_size: usize) -> Batch {
        let mut rng = Rng::for_stream(self.seed ^ 0x5EC, 0xE7A1_0000_0002);
        self.make_batch(&mut rng, batch_size)
    }

    fn feature_dim(&self) -> usize {
        self.seq * self.feat
    }

    fn num_classes(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let ds = SequenceDataset::new(4, 10, 5, 77);
        let b = ds.batch(2, 4, 3, 6);
        assert_eq!(b.x.len(), 6 * 10 * 4);
        assert_eq!(b.y.len(), 6 * 10);
        for &y in &b.y {
            assert!(y >= 0 && y < 5);
        }
    }

    #[test]
    fn labels_piecewise_constant() {
        let ds = SequenceDataset::new(2, 20, 4, 5);
        let b = ds.batch(0, 1, 0, 8);
        // count label changes per window: segments are ≥3 frames, so
        // changes ≤ seq/3
        for w in 0..8 {
            let ys = &b.y[w * 20..(w + 1) * 20];
            let changes = ys.windows(2).filter(|p| p[0] != p[1]).count();
            assert!(changes <= 7, "too many label changes: {changes}");
        }
    }

    #[test]
    fn signal_bounded() {
        let ds = SequenceDataset::new(4, 10, 5, 77);
        let b = ds.eval_batch(4);
        for &v in &b.x {
            assert!(v.abs() < 2.5, "signal out of range: {v}");
        }
    }
}
