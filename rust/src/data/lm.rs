//! Synthetic character-level language corpus (WMT stand-in).
//!
//! A randomly drawn order-2 Markov chain over a small vocabulary with a
//! Zipf-like stationary skew. The chain gives the corpus real predictive
//! structure (cross-entropy well below log|V|), so a transformer trained
//! on it shows genuine loss-curve dynamics — which is what the
//! convergence-parity experiments need from the language workload.
//!
//! Batches are token windows: features are the `seq` context tokens (as
//! f32 ids, embedded model-side), labels are the next-token targets for
//! every position.

use crate::data::{Batch, Dataset};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct LmCorpus {
    pub vocab: usize,
    pub seq: usize,
    seed: u64,
    /// transition logits table [vocab*vocab][vocab] (order-2), row-major.
    table: Vec<f32>,
}

impl LmCorpus {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> Self {
        assert!(vocab >= 2 && seq >= 2);
        let mut rng = Rng::for_stream(seed, 0x11A0);
        // Sparse-ish transition preferences: each (a,b) context strongly
        // prefers a few successors → learnable structure.
        let mut table = vec![0.0f32; vocab * vocab * vocab];
        for ctx in 0..vocab * vocab {
            let row = &mut table[ctx * vocab..(ctx + 1) * vocab];
            for v in row.iter_mut() {
                *v = rng.next_normal_f32(0.0, 1.0);
            }
            // boost 2 favored successors by a large margin
            for _ in 0..2 {
                let j = rng.next_below(vocab as u64) as usize;
                row[j] += 5.0;
            }
        }
        LmCorpus {
            vocab,
            seq,
            seed,
            table,
        }
    }

    /// Sample the next token given context (a, b) via Gumbel-max on the
    /// stored logits (temperature 1).
    fn next_token(&self, rng: &mut Rng, a: usize, b: usize) -> usize {
        let row = &self.table[(a * self.vocab + b) * self.vocab..];
        let mut best = 0;
        let mut best_v = f32::NEG_INFINITY;
        for j in 0..self.vocab {
            let u: f64 = rng.next_f64().max(1e-12);
            let g = -(-u.ln()).ln() as f32;
            let v = row[j] + g;
            if v > best_v {
                best_v = v;
                best = j;
            }
        }
        best
    }

    fn sample_window(&self, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(self.seq + 1);
        toks.push(rng.next_below(self.vocab as u64) as usize);
        toks.push(rng.next_below(self.vocab as u64) as usize);
        while toks.len() < self.seq + 1 {
            let a = toks[toks.len() - 2];
            let b = toks[toks.len() - 1];
            toks.push(self.next_token(rng, a, b));
        }
        let x: Vec<f32> = toks[..self.seq].iter().map(|&t| t as f32).collect();
        let y: Vec<i32> = toks[1..=self.seq].iter().map(|&t| t as i32).collect();
        (x, y)
    }

    fn make_batch(&self, rng: &mut Rng, batch_size: usize) -> Batch {
        let mut x = Vec::with_capacity(batch_size * self.seq);
        let mut y = Vec::with_capacity(batch_size * self.seq);
        for _ in 0..batch_size {
            let (bx, by) = self.sample_window(rng);
            x.extend(bx);
            y.extend(by);
        }
        Batch {
            x,
            y,
            batch: batch_size,
            feature_dim: self.seq,
        }
    }
}

impl Dataset for LmCorpus {
    fn batch(&self, worker: usize, n_workers: usize, step: usize, batch_size: usize) -> Batch {
        assert!(worker < n_workers);
        let stream = (step as u64) * (n_workers as u64) + worker as u64 + 1;
        let mut rng = Rng::for_stream(self.seed ^ 0x7A9C, stream);
        self.make_batch(&mut rng, batch_size)
    }

    fn eval_batch(&self, batch_size: usize) -> Batch {
        let mut rng = Rng::for_stream(self.seed ^ 0x7A9C, 0xE7A1_0000_0001);
        self.make_batch(&mut rng, batch_size)
    }

    fn feature_dim(&self) -> usize {
        self.seq
    }

    fn num_classes(&self) -> usize {
        self.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let c = LmCorpus::new(16, 8, 3);
        let b = c.batch(0, 2, 0, 4);
        for &t in &b.x {
            assert!(t >= 0.0 && (t as usize) < 16);
            assert_eq!(t.fract(), 0.0);
        }
        for &t in &b.y {
            assert!(t >= 0 && (t as usize) < 16);
        }
        assert_eq!(b.x.len(), 4 * 8);
        assert_eq!(b.y.len(), 4 * 8);
    }

    #[test]
    fn targets_shift_inputs() {
        let c = LmCorpus::new(16, 8, 3);
        let b = c.batch(0, 1, 0, 2);
        // y[i] == x[i+1] within each window
        for w in 0..2 {
            for i in 0..7 {
                assert_eq!(b.y[w * 8 + i], b.x[w * 8 + i + 1] as i32);
            }
        }
    }

    #[test]
    fn corpus_has_predictive_structure() {
        // Empirical conditional entropy under the true bigram context must
        // be far below log2(vocab): the favored successors dominate.
        let c = LmCorpus::new(8, 64, 11);
        let b = c.batch(0, 1, 0, 64);
        // count (ctx → next) empirical distribution over all windows
        let v = 8usize;
        let mut counts = vec![0u32; v * v * v];
        for w in 0..b.batch {
            let xs = &b.x[w * 64..(w + 1) * 64];
            let ys = &b.y[w * 64..(w + 1) * 64];
            for i in 1..64 {
                let a = xs[i - 1] as usize;
                let bb = xs[i] as usize;
                let y = ys[i] as usize;
                counts[(a * v + bb) * v + y] += 1;
            }
        }
        let total: f64 = counts.iter().map(|&c| c as f64).sum();
        // conditional entropy = -Σ_ctx (n_ctx/N) Σ p log p
        let mut h2 = 0.0f64;
        for ctx in 0..v * v {
            let row = &counts[ctx * v..(ctx + 1) * v];
            let n: u32 = row.iter().sum();
            if n == 0 {
                continue;
            }
            let mut hc = 0.0;
            for &c in row {
                if c > 0 {
                    let p = c as f64 / n as f64;
                    hc -= p * p.log2();
                }
            }
            h2 += hc * n as f64 / total;
        }
        assert!(
            h2 < 2.0,
            "conditional entropy {h2:.2} bits should be ≪ log2(8)=3"
        );
    }
}
