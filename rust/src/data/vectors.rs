//! Gaussian-cluster classification (vision stand-in).
//!
//! `num_classes` anisotropic gaussian clusters in `dim` dimensions with
//! class-dependent means and a shared covariance structure; within-class
//! noise makes per-worker gradients differ (the statistical similarity
//! the paper studies emerges from sample noise, not from identical data).

use crate::data::{Batch, Dataset};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ClusterDataset {
    pub dim: usize,
    pub classes: usize,
    seed: u64,
    /// class means, [classes][dim]
    means: Vec<Vec<f32>>,
    /// per-dimension noise scale
    noise: Vec<f32>,
}

impl ClusterDataset {
    pub fn new(dim: usize, classes: usize, seed: u64) -> Self {
        let mut rng = Rng::for_stream(seed, 0xC1A55);
        let means = (0..classes)
            .map(|_| {
                let mut m = vec![0.0f32; dim];
                rng.fill_normal(&mut m, 1.5);
                m
            })
            .collect();
        let noise = (0..dim)
            .map(|_| 0.4 + 0.6 * rng.next_f32())
            .collect();
        ClusterDataset {
            dim,
            classes,
            seed,
            means,
            noise,
        }
    }

    fn sample_into(&self, rng: &mut Rng, x: &mut [f32]) -> i32 {
        let c = rng.next_below(self.classes as u64) as usize;
        let mean = &self.means[c];
        for (i, v) in x.iter_mut().enumerate() {
            *v = mean[i] + rng.next_normal_f32(0.0, self.noise[i]);
        }
        c as i32
    }
}

impl Dataset for ClusterDataset {
    fn batch(&self, worker: usize, n_workers: usize, step: usize, batch_size: usize) -> Batch {
        assert!(worker < n_workers);
        // stream id encodes (worker, step): disjoint per-worker shards.
        let stream = (step as u64) * (n_workers as u64) + worker as u64 + 1;
        let mut rng = Rng::for_stream(self.seed, stream);
        let mut x = vec![0.0f32; batch_size * self.dim];
        let mut y = vec![0i32; batch_size];
        for b in 0..batch_size {
            y[b] = self.sample_into(&mut rng, &mut x[b * self.dim..(b + 1) * self.dim]);
        }
        Batch {
            x,
            y,
            batch: batch_size,
            feature_dim: self.dim,
        }
    }

    fn eval_batch(&self, batch_size: usize) -> Batch {
        let mut rng = Rng::for_stream(self.seed, EVAL_STREAM);
        let mut x = vec![0.0f32; batch_size * self.dim];
        let mut y = vec![0i32; batch_size];
        for b in 0..batch_size {
            y[b] = self.sample_into(&mut rng, &mut x[b * self.dim..(b + 1) * self.dim]);
        }
        Batch {
            x,
            y,
            batch: batch_size,
            feature_dim: self.dim,
        }
    }

    fn feature_dim(&self) -> usize {
        self.dim
    }

    fn num_classes(&self) -> usize {
        self.classes
    }
}

/// Stream id reserved for held-out evaluation batches.
const EVAL_STREAM: u64 = 0xE7A1_0000_0000;

/// Spatially-structured image classification (CNN stand-in for
/// ImageNet): each class is an oriented sinusoidal grating (distinct
/// angle + frequency) over a `side`×`side` image, plus pixel noise and a
/// random phase per sample. Convolutions genuinely help here — local
/// oriented-edge detectors are exactly what separates the classes —
/// unlike unstructured gaussian clusters.
#[derive(Debug, Clone)]
pub struct ImagePatternDataset {
    pub side: usize,
    pub classes: usize,
    seed: u64,
    /// per-class (angle, spatial frequency)
    params: Vec<(f32, f32)>,
}

impl ImagePatternDataset {
    pub fn new(side: usize, classes: usize, seed: u64) -> Self {
        let mut rng = Rng::for_stream(seed, 0x16A6E);
        let params = (0..classes)
            .map(|c| {
                let angle = std::f32::consts::PI * c as f32 / classes as f32
                    + 0.1 * rng.next_f32();
                let freq = 0.5 + 1.0 * rng.next_f32();
                (angle, freq)
            })
            .collect();
        ImagePatternDataset {
            side,
            classes,
            seed,
            params,
        }
    }

    fn sample_into(&self, rng: &mut Rng, x: &mut [f32]) -> i32 {
        let c = rng.next_below(self.classes as u64) as usize;
        let (angle, freq) = self.params[c];
        let phase = rng.next_f32() * 6.28;
        let (sa, ca) = (angle.sin(), angle.cos());
        for r in 0..self.side {
            for col in 0..self.side {
                let u = ca * col as f32 + sa * r as f32;
                let v = (freq * u + phase).sin() + 0.3 * rng.next_normal_f32(0.0, 1.0);
                x[r * self.side + col] = v;
            }
        }
        c as i32
    }
}

impl Dataset for ImagePatternDataset {
    fn batch(&self, worker: usize, n_workers: usize, step: usize, batch_size: usize) -> Batch {
        assert!(worker < n_workers);
        let stream = (step as u64) * (n_workers as u64) + worker as u64 + 1;
        let mut rng = Rng::for_stream(self.seed ^ 0x16A6, stream);
        let dim = self.side * self.side;
        let mut x = vec![0.0f32; batch_size * dim];
        let mut y = vec![0i32; batch_size];
        for b in 0..batch_size {
            y[b] = self.sample_into(&mut rng, &mut x[b * dim..(b + 1) * dim]);
        }
        Batch {
            x,
            y,
            batch: batch_size,
            feature_dim: dim,
        }
    }

    fn eval_batch(&self, batch_size: usize) -> Batch {
        let mut rng = Rng::for_stream(self.seed ^ 0x16A6, EVAL_STREAM);
        let dim = self.side * self.side;
        let mut x = vec![0.0f32; batch_size * dim];
        let mut y = vec![0i32; batch_size];
        for b in 0..batch_size {
            y[b] = self.sample_into(&mut rng, &mut x[b * dim..(b + 1) * dim]);
        }
        Batch {
            x,
            y,
            batch: batch_size,
            feature_dim: dim,
        }
    }

    fn feature_dim(&self) -> usize {
        self.side * self.side
    }

    fn num_classes(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_are_separable_on_average() {
        // A linear probe on the class means should beat chance easily:
        // check that nearest-mean classification of fresh samples is
        // mostly correct — i.e., the task is learnable.
        let ds = ClusterDataset::new(16, 4, 9);
        let b = ds.batch(0, 1, 0, 256);
        let mut correct = 0;
        for i in 0..b.batch {
            let x = &b.x[i * 16..(i + 1) * 16];
            let pred = (0..4)
                .min_by(|&a, &c| {
                    let da: f32 = x
                        .iter()
                        .zip(&ds.means[a])
                        .map(|(u, v)| (u - v) * (u - v))
                        .sum();
                    let dc: f32 = x
                        .iter()
                        .zip(&ds.means[c])
                        .map(|(u, v)| (u - v) * (u - v))
                        .sum();
                    da.partial_cmp(&dc).unwrap()
                })
                .unwrap();
            if pred as i32 == b.y[i] {
                correct += 1;
            }
        }
        assert!(correct > 200, "nearest-mean acc {correct}/256");
    }

    #[test]
    fn shards_disjoint_same_step() {
        let ds = ClusterDataset::new(8, 3, 5);
        let a = ds.batch(0, 2, 7, 16);
        let b = ds.batch(1, 2, 7, 16);
        assert_ne!(a.x, b.x);
    }
}
