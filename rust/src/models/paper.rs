//! Per-layer tables of the paper's benchmark networks.
//!
//! These feed the analytic performance model (Figures 1b/6/A8/A9) and the
//! per-layer compression-rate rule (§4). Layers are grouped by stage —
//! fidelity at the level the bandwidth-centric model [35] needs: total
//! parameters, total forward FLOPs/sample, and the conv-vs-fc split that
//! drives the FLOPs/gradient ratio.

/// One (grouped) layer of a paper network.
#[derive(Debug, Clone)]
pub struct PaperLayer {
    pub name: &'static str,
    /// trainable parameters (= gradient elements)
    pub params: usize,
    /// forward FLOPs per sample (multiply-accumulate counted as 2)
    pub fwd_flops: f64,
    /// exempt from compression (paper skips the first conv)
    pub compress: bool,
}

/// A paper benchmark network.
#[derive(Debug, Clone)]
pub struct PaperNet {
    pub name: &'static str,
    pub layers: Vec<PaperLayer>,
    /// paper's Table 2/3 compression rate for this model
    pub paper_rate_std: f64,
    /// per-worker minibatch in the paper's standard runs
    pub paper_batch_per_worker: usize,
}

impl PaperNet {
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.params).sum()
    }

    pub fn total_fwd_flops(&self) -> f64 {
        self.layers.iter().map(|l| l.fwd_flops).sum()
    }

    /// Training FLOPs/sample ≈ 3× forward (fwd + input-grad + weight-grad).
    pub fn train_flops_per_sample(&self) -> f64 {
        3.0 * self.total_fwd_flops()
    }

    /// Gradient bytes at fp32.
    pub fn gradient_bytes(&self) -> usize {
        self.total_params() * 4
    }

    /// Effective compression rate using the paper's FLOPs/gradient rule
    /// at per-worker batch size `bsz`. The §4 bands are stated for the
    /// reference batch of 32 ("this guidance is based on the per-worker
    /// mini-batch size, 32 for vision and speech"); the ratio scales
    /// linearly as the batch changes.
    pub fn rule_based_rate(&self, bsz: usize) -> f64 {
        let scale = bsz as f64 / 32.0;
        let mut sent = 0.0f64;
        for l in &self.layers {
            if !l.compress {
                sent += l.params as f64;
                continue;
            }
            let ratio = l.fwd_flops * scale / (l.params.max(1)) as f64;
            sent += l.params as f64 / crate::compress::rate::rate_for_flops_ratio(ratio);
        }
        self.total_params() as f64 / sent
    }
}

macro_rules! layer {
    ($name:expr, $params:expr, $flops:expr) => {
        PaperLayer {
            name: $name,
            params: $params,
            fwd_flops: $flops as f64,
            compress: true,
        }
    };
    ($name:expr, $params:expr, $flops:expr, nocompress) => {
        PaperLayer {
            name: $name,
            params: $params,
            fwd_flops: $flops as f64,
            compress: false,
        }
    };
}

/// ResNet18 on ImageNet-224: 11.69 M params, ~1.82 GFLOPs fwd.
fn resnet18() -> PaperNet {
    PaperNet {
        name: "resnet18",
        layers: vec![
            layer!("conv1_7x7", 9_408, 118e6, nocompress),
            layer!("stage1_2xbasic64", 147_968, 462e6),
            layer!("stage2_2xbasic128", 525_568, 411e6),
            layer!("stage3_2xbasic256", 2_099_712, 411e6),
            layer!("stage4_2xbasic512", 8_393_728, 411e6),
            layer!("fc1000", 513_000, 1.0e6),
        ],
        paper_rate_std: 112.0,
        paper_batch_per_worker: 32,
    }
}

/// ResNet50 on ImageNet-224: 25.56 M params, ~4.1 GFLOPs fwd.
fn resnet50() -> PaperNet {
    PaperNet {
        name: "resnet50",
        layers: vec![
            layer!("conv1_7x7", 9_408, 118e6, nocompress),
            layer!("stage1_3xbottleneck", 215_808, 680e6),
            layer!("stage2_4xbottleneck", 1_219_584, 1040e6),
            layer!("stage3_6xbottleneck", 7_098_368, 1470e6),
            layer!("stage4_3xbottleneck", 14_964_736, 811e6),
            layer!("fc1000", 2_049_000, 4.1e6),
        ],
        paper_rate_std: 96.0,
        paper_batch_per_worker: 32,
    }
}

/// MobileNetV2 (width 1.0) on ImageNet-224: 3.5 M params, ~0.3 GFLOPs fwd.
fn mobilenet_v2() -> PaperNet {
    PaperNet {
        name: "mobilenetv2",
        layers: vec![
            layer!("conv1_3x3", 864, 21.7e6, nocompress),
            layer!("bottlenecks_1-7", 551_000, 190e6),
            layer!("bottlenecks_8-17", 1_486_000, 76e6),
            layer!("conv_last_1x1", 412_160, 20.2e6),
            layer!("fc1000", 1_281_000, 2.56e6),
        ],
        paper_rate_std: 155.0,
        paper_batch_per_worker: 32,
    }
}

/// Transformer-base for WMT14 En-De: ~61 M trainable params (excluding
/// tied softmax); FLOPs counted per *token* — `paper_batch_per_worker`
/// is the token batch (2250 tokens/GPU × update freq 2 = 4.5k, §4).
fn transformer_base() -> PaperNet {
    // 6 enc + 6 dec layers, d=512, ffn=2048, 8 heads, vocab 32k shared.
    PaperNet {
        name: "transformer",
        layers: vec![
            layer!("embed_32k_x512", 16_384_000, 0.5e6),
            layer!("enc_6x_selfattn", 6 * 1_050_624, 6.0 * 2.1e6),
            layer!("enc_6x_ffn", 6 * 2_099_712, 6.0 * 4.2e6),
            layer!("dec_6x_selfattn", 6 * 1_050_624, 6.0 * 2.1e6),
            layer!("dec_6x_crossattn", 6 * 1_050_624, 6.0 * 2.1e6),
            layer!("dec_6x_ffn", 6 * 2_099_712, 6.0 * 4.2e6),
            // output projection is tied with the embedding (0 extra
            // params) but still costs a vocab-sized matmul per token
            layer!("out_proj_tied", 0, 33.6e6),
        ],
        paper_rate_std: 47.0,
        paper_batch_per_worker: 4500,
    }
}

/// 4-layer bidirectional LSTM acoustic model for SWB300 (Appendix E.5):
/// 1024 cells/layer (512 per direction), input 140/260, bottleneck 256,
/// 32k-state softmax — ~43 M params.
fn lstm_speech() -> PaperNet {
    // per direction per layer: 4 * (in+hid+1) * hid weights
    // layer1 in=140, layers 2-4 in=1024 (concat of both directions)
    let l1 = 2 * 4 * (140 + 512 + 1) * 512;
    let ln = 2 * 4 * (1024 + 512 + 1) * 512;
    PaperNet {
        name: "lstm-speech",
        layers: vec![
            layer!("bilstm1", l1, 2.0 * l1 as f64 * 21.0), // 21 unrolled frames
            layer!("bilstm2", ln, 2.0 * ln as f64 * 21.0),
            layer!("bilstm3", ln, 2.0 * ln as f64 * 21.0),
            layer!("bilstm4", ln, 2.0 * ln as f64 * 21.0),
            layer!("bottleneck256", 1024 * 256 + 256, 2.0 * 1024.0 * 256.0 * 21.0),
            layer!("softmax32k", 256 * 32_000 + 32_000, 2.0 * 256.0 * 32_000.0 * 21.0),
        ],
        paper_rate_std: 400.0,
        paper_batch_per_worker: 32,
    }
}

/// Look up a paper network by name.
pub fn paper_net(name: &str) -> anyhow::Result<PaperNet> {
    Ok(match name {
        "resnet18" => resnet18(),
        "resnet50" => resnet50(),
        "mobilenetv2" => mobilenet_v2(),
        "transformer" => transformer_base(),
        "lstm-speech" => lstm_speech(),
        other => anyhow::bail!(
            "unknown paper network '{other}' \
             (expected resnet18|resnet50|mobilenetv2|transformer|lstm-speech)"
        ),
    })
}

pub const ALL_PAPER_NETS: [&str; 5] = [
    "resnet18",
    "resnet50",
    "mobilenetv2",
    "transformer",
    "lstm-speech",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        // within 5% of the published totals
        let cases = [
            ("resnet18", 11.69e6),
            ("resnet50", 25.56e6),
            ("mobilenetv2", 3.5e6),
            ("transformer", 61e6),
            // Appendix E.5 architecture (4 bi-LSTM @1024 cells, input 140,
            // 256 bottleneck, 32k softmax) computes to ~30M params.
            ("lstm-speech", 30e6),
        ];
        for (name, expect) in cases {
            let net = paper_net(name).unwrap();
            let got = net.total_params() as f64;
            let rel = (got - expect).abs() / expect;
            assert!(rel < 0.12, "{name}: {got:.3e} vs {expect:.3e} ({rel:.2})");
        }
    }

    #[test]
    fn resnet_flops_in_published_range() {
        let r18 = paper_net("resnet18").unwrap();
        assert!((r18.total_fwd_flops() - 1.82e9).abs() / 1.82e9 < 0.05);
        let r50 = paper_net("resnet50").unwrap();
        assert!((r50.total_fwd_flops() - 4.1e9).abs() / 4.1e9 < 0.05);
    }

    #[test]
    fn rule_based_rate_orders_sensibly() {
        // ResNet conv stages have huge FLOPs/param → gentle rates;
        // Transformer is matmul-dominated with ~O(1) FLOPs/param at the
        // embedding → aggressive 400X there.
        let r18 = paper_net("resnet18").unwrap();
        let rate18 = r18.rule_based_rate(32);
        assert!(rate18 > 20.0, "resnet18 rule rate {rate18}");
        let lstm = paper_net("lstm-speech").unwrap();
        let rate_lstm = lstm.rule_based_rate(32);
        // speech model is fc-heavy → the paper uses 400X
        assert!(rate_lstm > 100.0, "lstm rule rate {rate_lstm}");
    }

    #[test]
    fn unknown_net_rejected() {
        assert!(paper_net("vgg16").is_err());
    }

    #[test]
    fn all_nets_enumerable() {
        for n in ALL_PAPER_NETS {
            let net = paper_net(n).unwrap();
            assert!(net.total_params() > 0);
            assert!(net.train_flops_per_sample() > net.total_fwd_flops());
            assert_eq!(net.gradient_bytes(), net.total_params() * 4);
        }
    }
}
