//! Trainable model zoo backed by AOT artifacts.
//!
//! Each entry names a model whose forward/backward graph was lowered by
//! `python/compile/aot.py` into `artifacts/<name>.hlo.txt` (train step:
//! `(params, x, y) → (loss, grads)`) and `artifacts/<name>_eval.hlo.txt`
//! (`(params, x, y) → (loss, correct)`), with shapes/layout recorded in
//! `artifacts/manifest.json`. The zoo holds the *experiment-facing*
//! metadata: which synthetic dataset drives it and which paper workload
//! it stands in for.

use crate::data::{ClusterDataset, Dataset, ImagePatternDataset, LmCorpus, SequenceDataset};

/// Task family — determines how batches map onto artifact inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// x: [B, F] f32, y: [B] i32
    Classify,
    /// x: [B, S] token ids (fed as i32), y: [B, S] i32
    LanguageModel,
    /// x: [B, S*F] f32 frames, y: [B, S] i32
    SequenceLabel,
}

/// Zoo entry.
#[derive(Debug, Clone)]
pub struct ZooModel {
    pub name: &'static str,
    pub task: TaskKind,
    /// paper workload this model stands in for (DESIGN.md §4)
    pub stands_in_for: &'static str,
    /// default per-worker batch the artifact was lowered with
    pub batch_per_worker: usize,
    /// dataset generator dimensions
    pub feature_dim: usize,
    pub seq_len: usize,
    pub num_classes: usize,
    /// default compression rate used in Table 2-style runs
    pub default_rate: usize,
}

impl ZooModel {
    /// Instantiate the model's synthetic dataset.
    pub fn dataset(&self, seed: u64) -> Box<dyn Dataset> {
        match self.task {
            // the conv model gets spatially-structured images (oriented
            // gratings); the mlp gets unstructured gaussian clusters
            TaskKind::Classify if self.name == "cnn" => Box::new(
                ImagePatternDataset::new(16, self.num_classes, seed),
            ),
            TaskKind::Classify => Box::new(ClusterDataset::new(
                self.feature_dim,
                self.num_classes,
                seed,
            )),
            TaskKind::LanguageModel => {
                Box::new(LmCorpus::new(self.num_classes, self.seq_len, seed))
            }
            TaskKind::SequenceLabel => Box::new(SequenceDataset::new(
                self.feature_dim,
                self.seq_len,
                self.num_classes,
                seed,
            )),
        }
    }

    pub fn train_artifact(&self) -> String {
        format!("{}.hlo.txt", self.name)
    }

    pub fn eval_artifact(&self) -> String {
        format!("{}_eval.hlo.txt", self.name)
    }
}

/// Look up a zoo model.
pub fn zoo_model(name: &str) -> anyhow::Result<ZooModel> {
    ALL_ZOO_MODELS
        .iter()
        .find(|m| m.name == name)
        .cloned()
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown zoo model '{name}' (expected one of: {})",
                ALL_ZOO_MODELS
                    .iter()
                    .map(|m| m.name)
                    .collect::<Vec<_>>()
                    .join("|")
            )
        })
}

/// All trainable models. Sizes are chosen so a multi-worker run of a few
/// hundred steps completes in seconds on the CPU PJRT backend while still
/// exhibiting real SGD dynamics (see DESIGN.md §4 substitutions).
pub const ALL_ZOO_MODELS: &[ZooModel] = &[
    ZooModel {
        name: "mlp",
        task: TaskKind::Classify,
        stands_in_for: "ResNet34/CIFAR10 (vision, small)",
        batch_per_worker: 32,
        feature_dim: 32,
        seq_len: 1,
        num_classes: 10,
        default_rate: 92,
    },
    ZooModel {
        name: "cnn",
        task: TaskKind::Classify,
        stands_in_for: "ResNet18-50+MobileNetV2/ImageNet (vision, large)",
        batch_per_worker: 32,
        feature_dim: 256, // 16x16 single-channel image
        seq_len: 1,
        num_classes: 10,
        default_rate: 112,
    },
    ZooModel {
        name: "transformer",
        task: TaskKind::LanguageModel,
        stands_in_for: "Transformer-base/WMT14 En-De (language)",
        batch_per_worker: 16,
        feature_dim: 16, // seq len
        seq_len: 16,
        num_classes: 32, // vocab
        default_rate: 47,
    },
    ZooModel {
        name: "transformer-med",
        task: TaskKind::LanguageModel,
        stands_in_for: "Transformer-base/WMT14 En-De (language, E2E driver)",
        batch_per_worker: 16,
        feature_dim: 32,
        seq_len: 32,
        num_classes: 64,
        default_rate: 47,
    },
    ZooModel {
        name: "lstm",
        task: TaskKind::SequenceLabel,
        stands_in_for: "4-bi-LSTM/SWB300 (speech)",
        batch_per_worker: 32,
        feature_dim: 8, // per-frame features
        seq_len: 12,
        num_classes: 6,
        default_rate: 400,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_and_artifacts() {
        let m = zoo_model("mlp").unwrap();
        assert_eq!(m.train_artifact(), "mlp.hlo.txt");
        assert_eq!(m.eval_artifact(), "mlp_eval.hlo.txt");
        assert!(zoo_model("alexnet").is_err());
    }

    #[test]
    fn datasets_instantiate_with_matching_dims() {
        for m in ALL_ZOO_MODELS {
            let ds = m.dataset(1);
            assert_eq!(ds.num_classes(), m.num_classes);
            let b = ds.batch(0, 2, 0, 4);
            b.validate();
            match m.task {
                TaskKind::Classify => {
                    assert_eq!(b.feature_dim, m.feature_dim);
                    assert_eq!(b.y.len(), 4);
                }
                TaskKind::LanguageModel => {
                    assert_eq!(b.feature_dim, m.seq_len);
                    assert_eq!(b.y.len(), 4 * m.seq_len);
                }
                TaskKind::SequenceLabel => {
                    assert_eq!(b.feature_dim, m.seq_len * m.feature_dim);
                    assert_eq!(b.y.len(), 4 * m.seq_len);
                }
            }
        }
    }

    #[test]
    fn every_model_covers_a_paper_domain() {
        let domains: Vec<&str> = ALL_ZOO_MODELS.iter().map(|m| m.stands_in_for).collect();
        assert!(domains.iter().any(|d| d.contains("vision")));
        assert!(domains.iter().any(|d| d.contains("language")));
        assert!(domains.iter().any(|d| d.contains("speech")));
    }
}
