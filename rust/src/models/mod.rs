//! Model registry: (a) the paper's five benchmark networks as per-layer
//! parameter/FLOP tables (consumed by the analytic performance model and
//! the compression-rate rule), and (b) the trainable model zoo backed by
//! AOT artifacts (consumed by the trainer).

pub mod paper;
pub mod zoo;

pub use paper::{paper_net, PaperLayer, PaperNet};
pub use zoo::{zoo_model, ZooModel};
