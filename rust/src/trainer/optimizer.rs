//! Optimizers over the flat parameter vector.
//!
//! Matching Appendix E: non-Nesterov SGD+momentum for the vision models,
//! RMSProp for MobileNetV2-like runs, Adam for the Transformer. The
//! update consumes the *averaged, already-LR-free* gradient g^t and the
//! current learning rate (Algorithm 1 applies α at line 12).

use crate::config::train::OptimizerKind;

pub trait Optimizer: Send {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f64);
    fn name(&self) -> &'static str;
}

/// Plain SGD: θ ← θ − α·g (optionally with decoupled weight decay).
pub struct Sgd {
    pub weight_decay: f32,
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f64) {
        let lr = lr as f32;
        let wd = self.weight_decay;
        for (p, &g) in params.iter_mut().zip(grad) {
            *p -= lr * (g + wd * *p);
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Non-Nesterov momentum SGD: v ← μv + g; θ ← θ − α·v.
pub struct SgdMomentum {
    pub momentum: f32,
    pub weight_decay: f32,
    v: Vec<f32>,
}

impl SgdMomentum {
    pub fn new(dim: usize, momentum: f32, weight_decay: f32) -> Self {
        SgdMomentum {
            momentum,
            weight_decay,
            v: vec![0.0; dim],
        }
    }
}

impl Optimizer for SgdMomentum {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f64) {
        let lr = lr as f32;
        let mu = self.momentum;
        let wd = self.weight_decay;
        for ((p, &g), v) in params.iter_mut().zip(grad).zip(&mut self.v) {
            let g = g + wd * *p;
            *v = mu * *v + g;
            *p -= lr * *v;
        }
    }

    fn name(&self) -> &'static str {
        "sgd-momentum"
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    b1: f32,
    b2: f32,
    eps: f32,
    t: i32,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    pub fn new(dim: usize) -> Self {
        Adam {
            b1: 0.9,
            b2: 0.98, // transformer setting (Vaswani et al.)
            eps: 1e-9,
            t: 0,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f64) {
        self.t += 1;
        let lr = lr as f32;
        let bc1 = 1.0 - self.b1.powi(self.t);
        let bc2 = 1.0 - self.b2.powi(self.t);
        for ((p, &g), (m, v)) in params
            .iter_mut()
            .zip(grad)
            .zip(self.m.iter_mut().zip(&mut self.v))
        {
            *m = self.b1 * *m + (1.0 - self.b1) * g;
            *v = self.b2 * *v + (1.0 - self.b2) * g * g;
            let mh = *m / bc1;
            let vh = *v / bc2;
            *p -= lr * mh / (vh.sqrt() + self.eps);
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

/// RMSProp with momentum (the MobileNetV2 recipe: ε=1.0 in the paper's
/// setup; we default to 1e-3 at our scale but keep it configurable).
pub struct RmsProp {
    decay: f32,
    momentum: f32,
    eps: f32,
    sq: Vec<f32>,
    v: Vec<f32>,
}

impl RmsProp {
    pub fn new(dim: usize, eps: f32) -> Self {
        RmsProp {
            decay: 0.9,
            momentum: 0.9,
            eps,
            sq: vec![0.0; dim],
            v: vec![0.0; dim],
        }
    }
}

impl Optimizer for RmsProp {
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f64) {
        let lr = lr as f32;
        for ((p, &g), (sq, v)) in params
            .iter_mut()
            .zip(grad)
            .zip(self.sq.iter_mut().zip(&mut self.v))
        {
            *sq = self.decay * *sq + (1.0 - self.decay) * g * g;
            let upd = g / (sq.sqrt() + self.eps);
            *v = self.momentum * *v + lr * upd;
            *p -= *v;
        }
    }

    fn name(&self) -> &'static str {
        "rmsprop"
    }
}

/// Factory from config.
pub fn make_optimizer(
    kind: OptimizerKind,
    dim: usize,
    momentum: f64,
    weight_decay: f64,
) -> Box<dyn Optimizer> {
    match kind {
        OptimizerKind::Sgd => Box::new(Sgd {
            weight_decay: weight_decay as f32,
        }),
        OptimizerKind::SgdMomentum => Box::new(SgdMomentum::new(
            dim,
            momentum as f32,
            weight_decay as f32,
        )),
        OptimizerKind::Adam => Box::new(Adam::new(dim)),
        OptimizerKind::RmsProp => Box::new(RmsProp::new(dim, 1e-3)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_converges(opt: &mut dyn Optimizer, lr: f64) -> f32 {
        // minimize 0.5*||p||^2; gradient = p
        let mut p = vec![1.0f32, -2.0, 3.0];
        for _ in 0..200 {
            let g = p.clone();
            opt.step(&mut p, &g, lr);
        }
        p.iter().map(|x| x.abs()).fold(0.0, f32::max)
    }

    #[test]
    fn all_optimizers_converge_on_quadratic() {
        assert!(quadratic_converges(&mut Sgd { weight_decay: 0.0 }, 0.1) < 1e-3);
        assert!(quadratic_converges(&mut SgdMomentum::new(3, 0.9, 0.0), 0.05) < 1e-3);
        assert!(quadratic_converges(&mut Adam::new(3), 0.05) < 1e-2);
        assert!(quadratic_converges(&mut RmsProp::new(3, 1e-3), 0.01) < 1e-2);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = SgdMomentum::new(1, 0.9, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 1.0);
        assert_eq!(p[0], -1.0);
        opt.step(&mut p, &[1.0], 1.0);
        // v = 0.9*1 + 1 = 1.9 → p = -1 - 1.9 = -2.9
        assert!((p[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut opt = Sgd { weight_decay: 0.1 };
        let mut p = vec![1.0f32];
        opt.step(&mut p, &[0.0], 0.5);
        assert!((p[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn factory_builds_each_kind() {
        for kind in [
            OptimizerKind::Sgd,
            OptimizerKind::SgdMomentum,
            OptimizerKind::Adam,
            OptimizerKind::RmsProp,
        ] {
            let o = make_optimizer(kind, 4, 0.9, 0.0);
            assert!(!o.name().is_empty());
        }
    }
}
