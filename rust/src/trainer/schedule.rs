//! Learning-rate schedules.
//!
//! Large-batch runs follow Goyal et al. [7] / Appendix E: linear warmup
//! from base to peak over the first steps, then decay. The Transformer
//! uses warmup + inverse-sqrt (Vaswani et al.).

use crate::config::train::ScheduleKind;

/// Resolved schedule: maps step → learning rate.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub kind: ScheduleKind,
    pub base_lr: f64,
    /// peak LR for warmup schedules (defaults to base_lr when no scaling)
    pub peak_lr: f64,
    pub total_steps: usize,
    /// step-decay boundaries as fractions of total (ResNet-style 30/60/90)
    pub decay_at: Vec<f64>,
}

impl LrSchedule {
    pub fn constant(lr: f64) -> Self {
        LrSchedule {
            kind: ScheduleKind::Constant,
            base_lr: lr,
            peak_lr: lr,
            total_steps: 0,
            decay_at: vec![],
        }
    }

    /// Paper-style large-batch schedule: linear warmup base→peak over
    /// `warmup` steps, then constant at peak.
    pub fn warmup_linear(base: f64, peak: f64, warmup: usize) -> Self {
        LrSchedule {
            kind: ScheduleKind::LinearWarmup { warmup },
            base_lr: base,
            peak_lr: peak,
            total_steps: 0,
            decay_at: vec![],
        }
    }

    /// Step decay by `gamma` at the given fractions of `total_steps`.
    pub fn step_decay(lr: f64, gamma: f64, total_steps: usize, at: Vec<f64>) -> Self {
        LrSchedule {
            kind: ScheduleKind::StepDecay { gamma },
            base_lr: lr,
            peak_lr: lr,
            total_steps,
            decay_at: at,
        }
    }

    pub fn warmup_invsqrt(peak: f64, warmup: usize) -> Self {
        LrSchedule {
            kind: ScheduleKind::WarmupInvSqrt { warmup },
            base_lr: 0.0,
            peak_lr: peak,
            total_steps: 0,
            decay_at: vec![],
        }
    }

    pub fn lr_at(&self, step: usize) -> f64 {
        match self.kind {
            ScheduleKind::Constant => self.base_lr,
            ScheduleKind::StepDecay { gamma } => {
                let mut lr = self.base_lr;
                for &frac in &self.decay_at {
                    if step as f64 >= frac * self.total_steps as f64 {
                        lr *= gamma;
                    }
                }
                lr
            }
            ScheduleKind::LinearWarmup { warmup } => {
                if warmup == 0 || step >= warmup {
                    self.peak_lr
                } else {
                    self.base_lr
                        + (self.peak_lr - self.base_lr) * (step as f64 / warmup as f64)
                }
            }
            ScheduleKind::WarmupInvSqrt { warmup } => {
                let w = warmup.max(1) as f64;
                let s = (step + 1) as f64;
                if s <= w {
                    self.peak_lr * s / w
                } else {
                    self.peak_lr * (w / s).sqrt()
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(1000), 0.1);
    }

    #[test]
    fn warmup_linear_ramps_then_holds() {
        let s = LrSchedule::warmup_linear(0.1, 0.8, 10);
        assert!((s.lr_at(0) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(5) - 0.45).abs() < 1e-12);
        assert_eq!(s.lr_at(10), 0.8);
        assert_eq!(s.lr_at(100), 0.8);
    }

    #[test]
    fn step_decay_at_fractions() {
        let s = LrSchedule::step_decay(1.0, 0.1, 100, vec![0.5, 0.75]);
        assert_eq!(s.lr_at(0), 1.0);
        assert_eq!(s.lr_at(49), 1.0);
        assert!((s.lr_at(50) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(75) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn invsqrt_peaks_at_warmup() {
        let s = LrSchedule::warmup_invsqrt(0.4, 8);
        let peak = s.lr_at(7);
        assert!((peak - 0.4).abs() < 1e-12);
        assert!(s.lr_at(3) < peak);
        assert!(s.lr_at(31) < peak);
        // invsqrt: lr(4w-1) = peak/2
        assert!((s.lr_at(31) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn warmup_zero_is_constant_peak() {
        let s = LrSchedule::warmup_linear(0.1, 0.8, 0);
        assert_eq!(s.lr_at(0), 0.8);
    }
}
