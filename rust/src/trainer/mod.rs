//! Synchronous data-parallel trainer: PJRT compute + Algorithm 1.
//!
//! Per step t (fully synchronous, as in the paper):
//!   1. every worker computes (loss_i, ∇f_i) on its disjoint shard via
//!      the AOT train artifact (L2 graph, PJRT CPU);
//!   2. the `Coordinator` runs Algorithm 1 (CLT-k + low-pass memory +
//!      compressed collectives) — or the dense baseline — producing the
//!      averaged update g^t;
//!   3. the optimizer applies θ ← θ − α_t · g^t (identically on every
//!      worker, so one parameter copy suffices in simulation).
//!
//! The coordination step runs on the configured `Backend`: `sequential`
//! loops over workers on one thread; `threaded` runs a scoped thread per
//! worker with channel collectives (`comm::parallel`); `pipelined` runs
//! a persistent worker pool (`runtime::pipelined`) whose lanes own the
//! error-feedback memories and overlap each step's memory update with
//! its in-flight collective; `socket` is the same pool with every
//! collective hop crossing a loopback TCP socket through the wire codec
//! (`comm::socket` — multi-process rings launch via `scalecom node`).
//! All four are deterministic — the mesh dataflow fixes every reduction
//! order — and parity-locked by `rust/tests/backend_parity.rs`, so
//! communication volume and convergence results are
//! backend-independent. The optimizer needs g^t before the next
//! forward/backward, so cross-step lookahead (`step_overlapped`) is
//! left to the collective benches — but with `--bucket-bytes` the
//! trainer overlaps *inside* each step: `Coordinator::step_bucketed`
//! walks layer-aligned buckets in backward order, each bucket's
//! collective in flight while the next bucket's selection computes.
//!
//! `use_kernel` routes compression through the L1 Pallas artifacts
//! (`<model>_compress` / `<model>_apply`) instead of the native Rust
//! compressor — same semantics (asserted by `rust/tests/kernel_parity`),
//! demonstrating the three-layer hot path end to end.

pub mod optimizer;
pub mod schedule;

pub use optimizer::{make_optimizer, Optimizer};
pub use schedule::LrSchedule;

use crate::comm::{Backend, BucketPlan, Fabric, FabricConfig, Topology};
use crate::compress::{schemes::make_compressor, EfMemory, Selection, SparseGrad};
use crate::config::train::TrainConfig;
use crate::coordinator::{Coordinator, Mode, StepResult};
use crate::data::Dataset;
use crate::metrics::RunLog;
use crate::runtime::{Engine, LoadedModel, Manifest};
use crate::util::timer::Timer;
use anyhow::{Context, Result};

/// Everything a per-step instrumentation hook can observe.
pub struct StepSnapshot<'a> {
    pub t: usize,
    pub lr: f64,
    pub losses: &'a [f32],
    pub grads: &'a [Vec<f32>],
    /// error-feedback gradients m_i + ∇f_i (pre-update)
    pub ef_grads: &'a [Vec<f32>],
    pub result: &'a StepResult,
    pub memories: &'a [EfMemory],
}

pub type Hook<'h> = Box<dyn FnMut(&StepSnapshot) + 'h>;

pub struct Trainer<'h> {
    pub cfg: TrainConfig,
    #[allow(dead_code)]
    engine: Engine,
    model: LoadedModel,
    dataset: Box<dyn Dataset>,
    pub coordinator: Coordinator,
    optimizer: Box<dyn Optimizer>,
    pub schedule: LrSchedule,
    pub params: Vec<f32>,
    /// route compression through the L1 Pallas artifacts
    pub use_kernel: bool,
    /// optional (step, new β) switch — Appendix E.2 raises β back to 1
    /// once the LR has decayed
    pub beta_switch: Option<(usize, f32)>,
    hook: Option<Hook<'h>>,
}

impl<'h> Trainer<'h> {
    /// Build a trainer from config, loading artifacts from
    /// `cfg.artifacts_dir`.
    pub fn from_config(cfg: TrainConfig) -> Result<Self> {
        cfg.validate()?;
        let dir = std::path::PathBuf::from(&cfg.artifacts_dir);
        let dir = if dir.join("manifest.json").exists() {
            dir
        } else {
            crate::runtime::default_artifacts_dir()
        };
        let manifest = Manifest::load(&dir)?;
        let engine = Engine::cpu()?;
        let model = engine
            .load_model(&manifest, &cfg.model)
            .with_context(|| format!("loading model '{}'", cfg.model))?;
        anyhow::ensure!(
            cfg.batch_per_worker == model.mm.batch,
            "config batch_per_worker={} but artifact was lowered with batch={} — \
             re-run `make artifacts` or adjust the config",
            cfg.batch_per_worker,
            model.mm.batch
        );
        let zoo = crate::models::zoo_model(&cfg.model)?;
        let dataset = zoo.dataset(cfg.seed);

        let dim = model.mm.dim;
        let fabric = Fabric::new(FabricConfig {
            workers: cfg.workers,
            topology: Topology::parse(&cfg.fabric_topology)?,
            bandwidth_gbps: cfg.fabric_bandwidth_gbps,
            latency_us: 1.0,
            fault: crate::comm::FaultSpec::None,
        });
        let k = (dim as f64 / cfg.compress.rate as f64).ceil() as usize;
        let mode = if cfg.compress.scheme == "none" {
            Mode::Dense
        } else {
            // per-layer budgets need budget-derived chunk sizes
            let scheme = if cfg.compress.use_flops_rule && cfg.compress.scheme == "scalecom" {
                "scalecom-auto"
            } else {
                cfg.compress.scheme.as_str()
            };
            Mode::Compressed(make_compressor(scheme, cfg.compress.rate, cfg.seed)?)
        };
        let mut coordinator = Coordinator::new(
            cfg.workers,
            dim,
            mode,
            cfg.compress.beta,
            k.max(1),
            fabric,
            cfg.compress.warmup_steps,
        );
        // The wire codec and the ring topology must be configured before
        // the pooled lanes are built (the endpoints latch both at
        // construction).
        coordinator.try_set_wire_codec(cfg.wire_codec()?)?;
        coordinator.try_set_group_size(cfg.group_size)?;
        // Fallible switch: the socket backend binds a loopback TCP mesh,
        // and a refused mesh should be a clean CLI error, not a panic.
        coordinator.try_set_backend(Backend::parse(&cfg.backend)?)?;
        if cfg.compress.use_flops_rule {
            let partition = model.mm.layers.clone();
            let ks = partition.per_layer_k(
                cfg.compress.rate as f64,
                cfg.batch_per_worker,
                true,
            );
            coordinator = coordinator.with_layered(partition, ks);
        }
        // Bucketed exchange (`--bucket-bytes`): layer-aligned buckets
        // over the model's layer partition, driven per bucket by
        // `Coordinator::step_bucketed` so collectives overlap the rest
        // of the step's selection compute. Bucketing rides on per-layer
        // budgets (buckets are layer-aligned so selection decomposes
        // exactly), so a flat-rate config gets the per-layer split of
        // its rate here.
        if cfg.bucket_bytes > 0 && cfg.compress.scheme != "none" {
            let partition = model.mm.layers.clone();
            if coordinator.layered.is_none() {
                let ks = partition.per_layer_k(
                    cfg.compress.rate as f64,
                    cfg.batch_per_worker,
                    false,
                );
                coordinator = coordinator.with_layered(partition.clone(), ks);
            }
            coordinator.set_bucket_plan(Some(BucketPlan::from_partition(
                &partition,
                cfg.bucket_bytes,
            )));
        }

        let optimizer =
            make_optimizer(cfg.optimizer, dim, cfg.momentum, cfg.weight_decay);
        let params = model.load_init_params()?;
        Ok(Trainer {
            schedule: LrSchedule::constant(cfg.lr),
            cfg,
            engine,
            model,
            dataset,
            coordinator,
            optimizer,
            params,
            use_kernel: false,
            beta_switch: None,
            hook: None,
        })
    }

    pub fn set_hook(&mut self, hook: Hook<'h>) {
        self.hook = Some(hook);
    }

    pub fn dim(&self) -> usize {
        self.model.mm.dim
    }

    /// Run the configured number of steps; returns the metrics log.
    pub fn run(&mut self) -> Result<RunLog> {
        anyhow::ensure!(
            !(self.use_kernel && self.coordinator.backend().is_pooled()),
            "--kernel-compress runs the L1 Pallas path on the in-process \
             backends (sequential | threaded) — the persistent pool owns its \
             memories lane-side, which the kernel's set_memory round-trip \
             cannot reach; use --backend sequential or threaded"
        );
        // Bucketed overlap: with a multi-bucket plan the trainer drives
        // the per-bucket scheduler — bucket b's collective is in flight
        // while bucket b−1's selection computes — instead of the
        // synchronous monolithic exchange.
        let bucketed = self
            .coordinator
            .bucket_plan()
            .map_or(false, |p| p.num_buckets() > 1);
        anyhow::ensure!(
            !(self.use_kernel && bucketed),
            "--kernel-compress and --bucket-bytes are mutually exclusive (the \
             Pallas compress artifact selects over the whole gradient)"
        );
        let mut log = RunLog::new(
            &format!(
                "{}_{}_w{}",
                self.cfg.model, self.cfg.compress.scheme, self.cfg.workers
            ),
            &[
                "step",
                "loss",
                "lr",
                "rate",
                "bytes_up",
                "bytes_down",
                "comm_time_s",
                "eval_loss",
                "eval_acc",
                "wall_s",
            ],
        );
        log.add_meta("model", &self.cfg.model);
        log.add_meta("scheme", &self.cfg.compress.scheme);
        log.add_meta("workers", &self.cfg.workers.to_string());
        log.add_meta("beta", &self.cfg.compress.beta.to_string());
        log.add_meta("global_batch", &self.cfg.global_batch().to_string());
        log.add_meta("wire_compression", &self.coordinator.wire_codec().label());

        let timer = Timer::new();
        let n = self.cfg.workers;
        for t in 0..self.cfg.steps {
            if let Some((at, beta)) = self.beta_switch {
                if t == at {
                    self.coordinator.set_beta(beta);
                }
            }
            // (1) per-worker forward/backward on disjoint shards
            let mut losses = Vec::with_capacity(n);
            let mut grads = Vec::with_capacity(n);
            for w in 0..n {
                let batch = self
                    .dataset
                    .batch(w, n, t, self.cfg.batch_per_worker);
                let (loss, g) = self.model.train_step(&self.params, &batch)?;
                losses.push(loss);
                grads.push(g);
            }

            // (2) Algorithm 1
            let need_efs = self.hook.is_some();
            let efs = if need_efs {
                self.coordinator.ef_grads(&grads)
            } else {
                Vec::new()
            };
            let result = if self.use_kernel
                && t >= self.cfg.compress.warmup_steps
                && !self.dense_scheme()
            {
                self.kernel_step(t, &grads)?
            } else if bucketed {
                // per-bucket overlap driver; lane faults (socket
                // backend) surface as clean errors, not panics
                self.coordinator.try_step_bucketed(t, &grads)?
            } else {
                self.coordinator.try_step(t, &grads)?
            };

            // (3) optimizer
            let lr = self.schedule.lr_at(t);
            self.optimizer.step(&mut self.params, &result.update, lr);

            if let Some(hook) = &mut self.hook {
                // The pooled backends (pipelined/socket) own their
                // memories on worker lanes, so hooks get a snapshot
                // there; the in-process backends keep the zero-copy
                // borrow.
                let snapshot;
                let memories: &[EfMemory] =
                    if self.coordinator.backend().is_pooled() {
                        snapshot = self.coordinator.memory_snapshot();
                        &snapshot
                    } else {
                        self.coordinator.memories()
                    };
                hook(&StepSnapshot {
                    t,
                    lr,
                    losses: &losses,
                    grads: &grads,
                    ef_grads: &efs,
                    result: &result,
                    memories,
                });
            }

            // (4) metrics
            let mean_loss =
                losses.iter().map(|&l| l as f64).sum::<f64>() / n as f64;
            let (eval_loss, eval_acc) = if self.cfg.eval_every > 0
                && (t + 1) % self.cfg.eval_every == 0
            {
                self.evaluate()?
            } else {
                (f64::NAN, f64::NAN)
            };
            log.push(vec![
                t as f64,
                mean_loss,
                lr,
                result.rate,
                result.comm.bytes_up_per_worker as f64,
                result.comm.bytes_down_per_worker as f64,
                result.comm.time_s,
                eval_loss,
                eval_acc,
                timer.elapsed_s(),
            ]);
        }
        // Socket backend: report what the wire actually shipped.
        let codec = self.coordinator.fabric.stats().codec.clone();
        if !codec.is_empty() {
            log.add_meta("wire_codec", &codec.summary());
        }
        Ok(log)
    }

    fn dense_scheme(&self) -> bool {
        self.cfg.compress.scheme == "none"
    }

    /// Held-out evaluation: (loss, accuracy in [0,1]).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let batch = self.dataset.eval_batch(self.cfg.batch_per_worker);
        let n_preds = batch.y.len() as f64;
        let (loss, correct) = self.model.eval_step(&self.params, &batch)?;
        Ok((loss as f64, correct as f64 / n_preds))
    }

    /// CLT-k step through the L1 Pallas artifacts (leader compresses +
    /// selects, followers apply the leader's indices; memory updates come
    /// back from the kernel). Runs on both in-process backends: the
    /// kernel calls themselves execute on the PJRT engine (one device),
    /// and the value exchange dispatches on the backend — the sequential
    /// fabric loop, or the threaded backend's real channel-ring
    /// collective over scoped worker threads, booked through the same
    /// `record_*` cost entry point (the parity contract).
    fn kernel_step(&mut self, t: usize, grads: &[Vec<f32>]) -> Result<StepResult> {
        let n = grads.len();
        let dim = self.model.mm.dim;
        let leader = t % n;
        // kernel path is in-process-backend-only (guarded in `run`), so
        // the memories are coordinator-local and directly borrowable
        let beta = self.coordinator.memories()[0].beta();

        let (idx, leader_vals, leader_mem) = self.model.kernel_compress(
            self.coordinator.memories()[leader].memory(),
            &grads[leader],
            beta,
        )?;
        let mut sparses: Vec<Option<SparseGrad>> = (0..n).map(|_| None).collect();
        sparses[leader] = Some(SparseGrad::new(dim, idx.clone(), leader_vals));
        let mut new_mems: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
        new_mems[leader] = Some(leader_mem);
        for w in 0..n {
            if w == leader {
                continue;
            }
            let (vals, mem) = self.model.kernel_apply(
                self.coordinator.memories()[w].memory(),
                &grads[w],
                &idx,
                beta,
            )?;
            sparses[w] = Some(SparseGrad::new(dim, idx.clone(), vals));
            new_mems[w] = Some(mem);
        }
        let sparses: Vec<SparseGrad> = sparses.into_iter().map(|s| s.unwrap()).collect();
        let avg = match self.coordinator.backend() {
            Backend::Sequential => self
                .coordinator
                .fabric
                .sparse_allreduce_shared(&sparses, leader),
            Backend::Threaded => {
                // ring all-reduce of the k selected values on scoped
                // worker threads — the same collective the threaded
                // top-k hot path uses — with identical cost booking
                let vals: Vec<Vec<f32>> =
                    sparses.iter().map(|s| s.values.clone()).collect();
                let reduced = crate::runtime::threaded::dense_allreduce_avg(&vals);
                self.coordinator
                    .fabric
                    .record_sparse_allreduce_shared(n, idx.len());
                SparseGrad::new(dim, idx.clone(), reduced)
            }
            Backend::Pipelined | Backend::Socket => {
                unreachable!("kernel path guarded to in-process backends in run()")
            }
        };
        for (mem, new) in self
            .coordinator
            .memories_mut()
            .iter_mut()
            .zip(new_mems.into_iter())
        {
            mem.set_memory(new.unwrap());
        }
        let comm = self.coordinator.fabric.stats().last_cost().clone();
        let sent = idx.len();
        Ok(StepResult {
            update: avg.to_dense(),
            selection: Some(Selection::Shared(idx)),
            leader,
            comm,
            rate: dim as f64 / sent.max(1) as f64,
            dense: false,
        })
    }
}
