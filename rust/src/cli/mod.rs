//! Command-line argument parsing (no clap offline — hand-rolled).
//!
//! Grammar: `scalecom <subcommand> [--key value] [--key=value] [--flag]`.
//! A `--key` followed by a token not starting with `--` is a valued
//! option; otherwise it is a boolean flag. Unknown keys are rejected by
//! `finish()` so typos fail loudly.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: BTreeSet<String>,
    consumed: BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    anyhow::bail!("bare '--' not supported");
                }
                if let Some(eq) = rest.find('=') {
                    let (k, v) = rest.split_at(eq);
                    out.values.insert(k.to_string(), v[1..].to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.values.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.values.get(key).cloned()
    }

    pub fn str_or(&mut self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&mut self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{s}'")),
        }
    }

    pub fn f64_or(&mut self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{s}'")),
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.consumed.insert(key.to_string());
        self.flags.contains(key)
    }

    /// Error on any unconsumed option (call after all accessors).
    pub fn finish(&self) -> anyhow::Result<()> {
        let unknown: Vec<&String> = self
            .values
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(*k))
            .collect();
        if !unknown.is_empty() {
            anyhow::bail!(
                "unknown option(s): {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
        Ok(())
    }
}

pub const USAGE: &str = "\
scalecom — ScaleCom (NeurIPS 2020) reproduction: sparsified gradient
compression for communication-efficient distributed training.

USAGE:
  scalecom <subcommand> [options]

SUBCOMMANDS:
  train            run a distributed training job
                     --model mlp|cnn|transformer|transformer-med|lstm
                     --workers N --steps N --scheme scalecom|local-topk|...
                     --rate R --beta B --lr LR --topology ps|ring
                     --backend sequential|threaded|pipelined|socket
                       (threaded: scoped thread-per-worker engine;
                        pipelined: persistent pool, overlaps compute/comm;
                        socket: that pool over loopback TCP — needs
                        --peers loopback)
                     --bucket-bytes N|auto  bucketed gradient exchange:
                       cap for the layer-aligned buckets scheduled per
                       step, so each bucket's collective overlaps the next
                       bucket's selection compute (0 = monolithic; implies
                       per-layer budgets; auto = run the calibrated tune
                       sweep and train with the winning plan)
                     --wire-compression off|delta|full  wire entropy codec
                       for the socket backend (delta: varint-packed sparse
                       index frames; full: + adaptive byte compression of
                       every large frame; default off, also settable via
                       SCALECOM_WIRE_COMPRESSION; flag > env > config)
                     --wire-compression-dense auto|raw|lz1|lz2 and
                     --wire-compression-sparse ...  pin the byte-compressor
                       per frame family (default auto = size-tiered)
                     --group-size G  hierarchical ring-of-rings for the
                       dense collective on the pooled backends: consecutive
                       groups of G workers run intra rings and the group
                       leaders run a level-1 uplink ring (0 = flat ring;
                       G must divide the worker count and leave >= 2
                       groups)
                     --trace-out FILE  record step phases + comm spans and
                       write a Chrome-trace/Perfetto JSON on exit (tracing
                       is off — a benched no-op — without this flag)
                     --config file.toml (flags override file)
  simulate         run the real coordination code at paper scale under
                   simulated link timing (deterministic virtual time)
                     --workers N (default 64) or --sweep-workers 8,16,64,256
                     --scheme all|local-topk|scalecom|gtop-k|sketch-k|true-topk
                     --profile uniform|hetero|hier|straggler|path/to.toml
                     --dim N --rate R --steps N --layers L --seed S
                     --bucket-bytes N --overlapped --compute-per-elem-ns X
                     --trace (print a per-op rollup of the virtual event
                       timeline) --trace-out FILE (write the full event
                       list as Chrome-trace JSON in the same schema the
                       real runtimes emit — `scalecom trace diff` compares
                       it against a measured trace; single scheme + worker
                       count only)
                     --elastic-kill-step T  elastic membership: kill one
                       worker at step T's exchange and charge the whole
                       recovery wave (2x-heartbeat detection, restart,
                       re-rendezvous, ring resume agreement, replay) in
                       virtual time — selections stay bit-identical to
                       the fault-free run
                     --elastic-kill-worker W (default 1)
                     --elastic-heartbeat-ms H (default 100)
                     --elastic-restart-ms R (default 1000)
                     --job-storm N  replay N synthetic submissions against
                       the serve scheduler in virtual time (deterministic
                       backpressure + FIFO-fairness report; no daemon)
                     --storm-max-queue N --storm-max-concurrent N
                     --storm-submit-every-ms X --storm-job-ms X
  tune             pick --bucket-bytes: calibrate compute from real
                   steps, sweep every bucket plan (+ the overlapped
                   driving mode) through the simulator, print the winner
                     --workers N --dim N --scheme S --rate R --layers L
                     --profile ... --steps N --calibration-steps N
                     --compute-per-elem-ns X (skip calibration)
  node             one node of a multi-process socket ring (N processes,
                   localhost or N hosts); rank 0 emits the parity digest;
                   SIGINT/SIGTERM drains: the fleet agrees on a stop step
                   (ring ballot) and exits with clean EOFs and a parseable
                   partial digest
                     --role coordinator|worker
                     --bind HOST:PORT (this node's address)
                     --peers ADDR0,ADDR1,... (every node, coordinator
                       first, identical on every node; rank = position
                       of --bind in the list)
                     --scheme S --dim N --rate R --steps N --seed S
                     --beta B --compress-warmup N --topology ps|ring
                     --timeout-secs N --step-delay-ms N
                     --wire-compression off|delta|full (must match on
                       every node of the ring; old peers are rejected at
                       the handshake) --wire-compression-dense ...
                       --wire-compression-sparse ...
                     --heartbeat-ms N  wire-level liveness: a dead or
                       wedged peer is detected within 2N ms instead of at
                       the next blocking read (0 = off; must match on
                       every node — the handshake rejects mixed meshes)
                     --reconnect  survive link faults: re-rendezvous on
                       the same listener, agree on a resume point (ring
                       min-reduce of newest snapshots), roll the EF memory
                       back, replay — digest stays bit-identical to a
                       fault-free run
                     --snapshot-dir DIR  persist the EF-memory snapshot
                       after every step (atomic rename), so a restarted
                       process can rejoin and resume; per-run scratch
                     --max-reconnect-attempts N (default 3)
                     --group-size G  hierarchical ring-of-rings: ranks are
                       tiled into consecutive groups of G, dense traffic
                       runs intra-ring + leader uplink ring + downlink
                       broadcast (0 = flat ring; must match on every node,
                       divide the node count, and leave >= 2 groups)
                     --trace-out FILE  per-process Chrome-trace JSON; the
                       post-rendezvous point is the clock-sync anchor, so
                       `scalecom trace merge` aligns the per-rank files
  serve            multi-tenant training daemon: one persistent shared
                   lane mesh, a bounded FIFO job queue with admission
                   control, the framed client protocol (wire codec v5),
                   and a Prometheus-style GET /metrics endpoint; runs
                   until SIGINT/SIGTERM, then drains
                     --bind HOST:PORT (default 127.0.0.1:7070, or
                       SCALECOM_SERVE_ADDR; flag > env > default)
                     --metrics-bind HOST:PORT (default 127.0.0.1:7071)
                     --workers N  lane-mesh width (every job runs with
                       this many workers; default 2)
                     --max-queue N  wait-queue capacity — overflow gets a
                       typed JobRejected (default 8, or
                       SCALECOM_SERVE_MAX_QUEUE)
                     --max-concurrent N  jobs sharing the lanes at once
                       (default 2)
                     --lane-transport channel|socket (default socket)
                     --metrics-job-retention N  finished jobs keeping
                       their per-job /metrics series (default 64; older
                       finished series are pruned so scrape cardinality
                       stays bounded)
                     --trace-out FILE  scheduler + job-step trace
                     --group-size G --wire-compression ... as for train
  submit           submit a job spec to a serve daemon and stream its
                   progress + digest
                     scalecom submit scheme=scalecom steps=20 seed=7
                     --spec 'k=v ...' (alternative to bare tokens)
                     --addr HOST:PORT (default SCALECOM_SERVE_ADDR or
                       127.0.0.1:7070) --no-follow --timeout-secs N
                     --local --workers N  run the same spec in-process
                       (no daemon) — the digest-parity reference
  status           one-line daemon summary (queue depth, counters, lane
                   health): --addr as for submit
  jobs             per-job table (state, progress, spec): --addr ...
  cancel           cancel a job: --job ID --addr ... (queued jobs are
                   dequeued; running jobs stop at a step boundary)
  trace            offline tooling over --trace-out Chrome-trace files
                     scalecom trace merge --out m.json r0.json r1.json ...
                       (rebase per-rank files onto their handshake sync
                       anchors, one pid track per rank)
                     scalecom trace report f.json  (per-category totals +
                       per-rank compute/comm overlap efficiency)
                     scalecom trace diff measured.json predicted.json
                       (per-phase predicted-vs-measured deltas, e.g. a
                       real node run against `simulate --trace-out`)
  bench-trend      compare two bench_allreduce --json artifacts and fail
                   on median regressions past the budget (the CI perf
                   gate); a missing or empty baseline skips the gate
                     --baseline old.json --current new.json
                     --max-regress 0.15 --prefixes allreduce,codec/
  experiment <id>  regenerate a paper table/figure:
                     table1 fig1a fig1b fig1c fig2 fig3 table2 table3
                     fig6 figA1 figA8  (or 'all')
  perf-model       analytic end-to-end performance model
                     --net resnet50 --workers N --batch B --tflops T
  compress-bench   compressor micro-benchmarks (Table 1 overhead column)
  artifacts-check  validate artifacts/ against the manifest and smoke-run
  list             list models, schemes, paper networks, experiments
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_values() {
        let mut a = parse(&["train", "--model", "mlp", "--steps=50", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_or("model", "x"), "mlp");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 50);
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["experiment", "fig2"]);
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig2"]);
    }

    #[test]
    fn flag_vs_value_disambiguation() {
        let mut a = parse(&["x", "--quick", "--n", "3"]);
        assert!(a.flag("quick"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn unknown_options_rejected() {
        let mut a = parse(&["x", "--typo", "1"]);
        let _ = a.str_opt("correct");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_numbers_rejected() {
        let mut a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
        let mut a = parse(&["x", "--f", "x.y"]);
        assert!(a.f64_or("f", 0.0).is_err());
    }

    #[test]
    fn defaults_applied() {
        let mut a = parse(&["x"]);
        assert_eq!(a.str_or("missing", "d"), "d");
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(a.f64_or("missing", 1.5).unwrap(), 1.5);
        assert!(!a.flag("missing"));
    }
}
