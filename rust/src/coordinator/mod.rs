//! Algorithm 1 — the ScaleCom coordination step, decoupled from PJRT.
//!
//! The `Coordinator` owns the per-worker error-feedback memories, the
//! compression scheme, and the fabric; `step` consumes this iteration's
//! stochastic gradients (however they were computed) and produces the
//! averaged update `g^t` plus full per-step diagnostics. The PJRT trainer
//! drives it with real gradients; unit/property tests drive it with
//! synthetic ones.
//!
//! Per Algorithm 1:
//!   line 6: g_i = CLT_{mod(t,n)}(m_i + ∇f_i)        → `select` + sparsify
//!   line 7: m_i ← (1-β)m_i + β(m_i + ∇f_i − g_i)    → EfMemory update
//!   lines 9-11: upload/reduce/download               → Fabric collectives
//!   (warmup steps and uncompressed layers go dense, per §4)

use crate::comm::{Backend, CommCost, Fabric};
use crate::compress::{
    sparsify, Compressor, EfMemory, LayerPartition, Selection, SparseGrad,
};
use crate::runtime::threaded;

/// What happened in one coordination step (for metrics + experiments).
pub struct StepResult {
    /// averaged update g^t to feed the optimizer (dense, full dim)
    pub update: Vec<f32>,
    /// index selection used (None during dense warmup)
    pub selection: Option<Selection>,
    /// cyclic leader of this step
    pub leader: usize,
    /// communication cost of the gradient exchange
    pub comm: CommCost,
    /// achieved compression rate this step (dim / transmitted coords)
    pub rate: f64,
    /// whether the dense path was used (warmup / scheme none)
    pub dense: bool,
}

/// Coordination mode.
pub enum Mode {
    /// No compression — dense all-reduce baseline.
    Dense,
    /// Error-feedback sparsification with the given scheme.
    Compressed(Box<dyn Compressor>),
}

pub struct Coordinator {
    n: usize,
    dim: usize,
    mode: Mode,
    pub memories: Vec<EfMemory>,
    pub fabric: Fabric,
    /// flat per-step budget: either a single k over the whole vector...
    pub k: usize,
    /// ...or a per-layer budget (paper's FLOPs/gradient rule).
    pub layered: Option<(LayerPartition, Vec<usize>)>,
    /// dense warmup steps (paper: 1-5 epochs uncompressed)
    pub warmup_steps: usize,
    /// execution backend: sequential loops or thread-per-worker engine
    /// (parity-locked in `rust/tests/backend_parity.rs`)
    pub backend: Backend,
}

impl Coordinator {
    pub fn new(
        n: usize,
        dim: usize,
        mode: Mode,
        beta: f32,
        k: usize,
        fabric: Fabric,
        warmup_steps: usize,
    ) -> Self {
        assert!(n >= 1 && dim >= 1);
        assert_eq!(fabric.workers(), n, "fabric sized for a different n");
        let memories = (0..n).map(|_| EfMemory::new(dim, beta)).collect();
        Coordinator {
            n,
            dim,
            mode,
            memories,
            fabric,
            k: k.clamp(1, dim),
            layered: None,
            warmup_steps,
            backend: Backend::Sequential,
        }
    }

    pub fn with_layered(mut self, partition: LayerPartition, ks: Vec<usize>) -> Self {
        assert_eq!(partition.total_len(), self.dim);
        assert_eq!(partition.layers.len(), ks.len());
        self.layered = Some((partition, ks));
        self
    }

    /// Select the execution backend (defaults to `Sequential`).
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    pub fn workers(&self) -> usize {
        self.n
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn set_beta(&mut self, beta: f32) {
        for m in &mut self.memories {
            m.set_beta(beta);
        }
    }

    /// Error-feedback gradients m_i + ∇f_i for all workers.
    pub fn ef_grads(&self, grads: &[Vec<f32>]) -> Vec<Vec<f32>> {
        assert_eq!(grads.len(), self.n);
        self.memories
            .iter()
            .zip(grads)
            .map(|(m, g)| m.ef_grad(g))
            .collect()
    }

    /// One coordination step over this iteration's stochastic gradients.
    pub fn step(&mut self, t: usize, grads: &[Vec<f32>]) -> StepResult {
        assert_eq!(grads.len(), self.n, "need one gradient per worker");
        for (w, g) in grads.iter().enumerate() {
            assert_eq!(g.len(), self.dim, "worker {w} gradient dim");
        }
        let leader = t % self.n;

        let dense_path = matches!(self.mode, Mode::Dense) || t < self.warmup_steps;
        if dense_path {
            let update = match self.backend {
                Backend::Sequential => self.fabric.dense_allreduce_avg(grads),
                Backend::Threaded => {
                    let out = threaded::dense_allreduce_avg(grads);
                    self.fabric.record_dense_allreduce(grads.len(), self.dim);
                    out
                }
            };
            let comm = self.fabric.stats().last_cost().clone();
            return StepResult {
                update,
                selection: None,
                leader,
                comm,
                rate: 1.0,
                dense: true,
            };
        }

        // --- compressed path -------------------------------------------
        let efs = match self.backend {
            Backend::Sequential => self.ef_grads(grads),
            Backend::Threaded => threaded::parallel_ef_grads(&self.memories, grads),
        };
        let ef_views: Vec<&[f32]> = efs.iter().map(|e| e.as_slice()).collect();
        let backend = self.backend;
        let n = self.n;
        // Selection fan-out follows the machine, not the simulated worker
        // count: 64 simulated workers on a 4-core box must not spawn 64
        // scan threads (results are thread-count-independent by the
        // `select_parallel` contract).
        let threads = match backend {
            Backend::Sequential => 1,
            Backend::Threaded => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
        };
        let compressor = match &mut self.mode {
            Mode::Compressed(c) => c,
            Mode::Dense => unreachable!(),
        };
        let selection = if let Some((partition, ks)) = &self.layered {
            select_layered(compressor.as_mut(), t, &ef_views, partition, ks, threads)
        } else if threads > 1 {
            compressor.select_parallel(t, &ef_views, self.k, threads)
        } else {
            compressor.select(t, &ef_views, self.k)
        };

        let (update, comm, sent) = match (&selection, backend) {
            (Selection::Shared(idx), Backend::Sequential) => {
                let sparses: Vec<SparseGrad> =
                    efs.iter().map(|ef| sparsify(ef, idx)).collect();
                let avg = self.fabric.sparse_allreduce_shared(&sparses, leader);
                (
                    avg.to_dense(),
                    self.fabric.stats().last_cost().clone(),
                    idx.len(),
                )
            }
            (Selection::Shared(idx), Backend::Threaded) => {
                // sparsify + ring reduce + memory update on worker threads
                let vals =
                    threaded::exchange_shared(&mut self.memories, grads, &efs, idx);
                let comm = self.fabric.record_sparse_allreduce_shared(n, idx.len());
                let avg = SparseGrad::new(self.dim, idx.clone(), vals);
                (avg.to_dense(), comm, idx.len())
            }
            (Selection::PerWorker(per), Backend::Sequential) => {
                let sparses: Vec<SparseGrad> = efs
                    .iter()
                    .zip(per)
                    .map(|(ef, idx)| sparsify(ef, idx))
                    .collect();
                let avg = self.fabric.sparse_gather_avg(&sparses);
                let sent = per.iter().map(|p| p.len()).max().unwrap_or(0);
                (avg, self.fabric.stats().last_cost().clone(), sent)
            }
            (Selection::PerWorker(per), Backend::Threaded) => {
                // sparsify + star gather + memory update on worker threads
                let (avg, gs) =
                    threaded::exchange_gather(&mut self.memories, grads, &efs, per);
                let comm = self.fabric.record_sparse_gather(&gs);
                let sent = per.iter().map(|p| p.len()).max().unwrap_or(0);
                (avg, comm, sent)
            }
        };

        // memory update (Eqn. 5) with each worker's transmitted indices —
        // the threaded exchanges already updated each memory on its
        // worker's thread.
        if backend == Backend::Sequential {
            for (w, mem) in self.memories.iter_mut().enumerate() {
                mem.update_after_send(&grads[w], selection.indices_for(w));
            }
        }

        StepResult {
            update,
            rate: self.dim as f64 / sent.max(1) as f64,
            selection: Some(selection),
            leader,
            comm,
            dense: false,
        }
    }
}

/// Apply a compressor independently per layer slice with per-layer k,
/// concatenating the global index sets (the §4 per-layer rate rule).
/// `threads > 1` routes each layer's scan through `select_parallel`
/// (identical output — the parity contract), so the threaded backend's
/// selection speedup also applies to flops-rule configs.
pub fn select_layered(
    compressor: &mut dyn Compressor,
    t: usize,
    efs: &[&[f32]],
    partition: &LayerPartition,
    ks: &[usize],
    threads: usize,
) -> Selection {
    let n = efs.len();
    let mut shared: Vec<u32> = Vec::new();
    let mut per_worker: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut any_per_worker = false;
    for (layer, &k) in partition.layers.iter().zip(ks) {
        let views: Vec<&[f32]> = efs
            .iter()
            .map(|ef| &ef[layer.offset..layer.offset + layer.len])
            .collect();
        let sel = if !layer.compress || k >= layer.len {
            // dense layer: every coordinate selected
            Selection::Shared((0..layer.len as u32).collect())
        } else if threads > 1 {
            compressor.select_parallel(t, &views, k, threads)
        } else {
            compressor.select(t, &views, k)
        };
        match sel {
            Selection::Shared(idx) => {
                let off = layer.offset as u32;
                shared.extend(idx.iter().map(|&i| i + off));
                for pw in &mut per_worker {
                    pw.extend(idx.iter().map(|&i| i + off));
                }
            }
            Selection::PerWorker(per) => {
                any_per_worker = true;
                let off = layer.offset as u32;
                for (w, idx) in per.iter().enumerate() {
                    per_worker[w].extend(idx.iter().map(|&i| i + off));
                }
            }
        }
    }
    if any_per_worker {
        Selection::PerWorker(per_worker)
    } else {
        Selection::Shared(shared)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{FabricConfig, Topology};
    use crate::compress::rate::LayerSlice;
    use crate::compress::schemes::{CltK, LocalTopK, TrueTopK};
    use crate::proptest::check;
    use crate::util::floats::allclose;
    use crate::util::rng::Rng;

    fn fabric(n: usize) -> Fabric {
        Fabric::new(FabricConfig {
            workers: n,
            topology: Topology::ParameterServer,
            ..FabricConfig::default()
        })
    }

    fn rand_grads(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| {
                let mut v = vec![0.0; dim];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn dense_mode_averages_exactly() {
        let mut c = Coordinator::new(2, 3, Mode::Dense, 1.0, 3, fabric(2), 0);
        let r = c.step(0, &[vec![1.0, 2.0, 3.0], vec![3.0, 2.0, 1.0]]);
        assert_eq!(r.update, vec![2.0, 2.0, 2.0]);
        assert!(r.dense);
        assert_eq!(r.rate, 1.0);
        assert!(r.selection.is_none());
    }

    #[test]
    fn warmup_steps_go_dense_then_compress() {
        let mut c = Coordinator::new(
            2,
            10,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            2,
            fabric(2),
            3,
        );
        let mut rng = Rng::new(5);
        for t in 0..5 {
            let r = c.step(t, &rand_grads(&mut rng, 2, 10));
            assert_eq!(r.dense, t < 3, "step {t}");
        }
    }

    #[test]
    fn clt_k_leader_cycles() {
        let n = 3;
        let mut c = Coordinator::new(
            n,
            12,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            2,
            fabric(n),
            0,
        );
        let mut rng = Rng::new(7);
        for t in 0..6 {
            let r = c.step(t, &rand_grads(&mut rng, n, 12));
            assert_eq!(r.leader, t % n);
            assert!(matches!(r.selection, Some(Selection::Shared(_))));
            assert_eq!(r.rate, 6.0);
        }
    }

    #[test]
    fn error_feedback_no_information_lost_beta1() {
        // Invariant: with β=1, sum over steps of updates + final averaged
        // memory == running average of all raw gradients, coordinate-wise.
        check("EF conservation over trajectory", 25, |g| {
            let n = g.usize_in(2..=4);
            let dim = g.usize_in(4..=64);
            let k = g.usize_in(1..=dim);
            let steps = g.usize_in(1..=10);
            let mut c = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                1.0,
                k,
                fabric(n),
                0,
            );
            let mut total_grads = vec![0.0f64; dim];
            let mut total_updates = vec![0.0f64; dim];
            for t in 0..steps {
                let grads: Vec<Vec<f32>> =
                    (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
                for w in &grads {
                    for (acc, &v) in total_grads.iter_mut().zip(w) {
                        *acc += v as f64 / n as f64;
                    }
                }
                let r = c.step(t, &grads);
                for (acc, &v) in total_updates.iter_mut().zip(&r.update) {
                    *acc += v as f64;
                }
            }
            // add back what's still in memory (averaged over workers)
            for mem in &c.memories {
                for (acc, &v) in total_updates.iter_mut().zip(mem.memory()) {
                    *acc += v as f64 / n as f64;
                }
            }
            for i in 0..dim {
                assert!(
                    (total_grads[i] - total_updates[i]).abs() < 1e-3,
                    "coord {i}: grads {} vs updates+memory {}",
                    total_grads[i],
                    total_updates[i]
                );
            }
        });
    }

    #[test]
    fn shared_vs_gather_byte_scaling() {
        // CLT-k per-worker download constant in n; local top-k grows.
        let dim = 2000;
        let k = 20;
        let mut scalecom_down = Vec::new();
        let mut localtopk_down = Vec::new();
        for n in [2usize, 8] {
            let mut rng = Rng::new(3);
            let grads = rand_grads(&mut rng, n, dim);
            let mut c1 = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                1.0,
                k,
                fabric(n),
                0,
            );
            scalecom_down.push(c1.step(0, &grads).comm.bytes_down_per_worker);
            let mut c2 = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(LocalTopK::new())),
                1.0,
                k,
                fabric(n),
                0,
            );
            localtopk_down.push(c2.step(0, &grads).comm.bytes_down_per_worker);
        }
        assert_eq!(scalecom_down[0], scalecom_down[1]);
        assert!(localtopk_down[1] > localtopk_down[0] * 2);
    }

    #[test]
    fn true_topk_contracts_at_least_as_well_as_clt_k() {
        // γ̂(true top-k) ≤ γ̂(CLT-k) on the averaged EF gradient.
        let n = 4;
        let dim = 256;
        let k = 16;
        let mut rng = Rng::new(11);
        let grads = rand_grads(&mut rng, n, dim);
        let mk = |m: Mode| Coordinator::new(n, dim, m, 1.0, k, fabric(n), 0);
        let mut c_true = mk(Mode::Compressed(Box::new(TrueTopK)));
        let mut c_clt = mk(Mode::Compressed(Box::new(CltK::exact())));

        let avg_ef = |c: &Coordinator, grads: &[Vec<f32>]| -> Vec<f32> {
            let efs = c.ef_grads(grads);
            let mut avg = vec![0.0f32; dim];
            for e in &efs {
                for (a, &v) in avg.iter_mut().zip(e) {
                    *a += v / n as f32;
                }
            }
            avg
        };
        let y = avg_ef(&c_true, &grads);
        let sel_true = match c_true.step(0, &grads).selection.unwrap() {
            Selection::Shared(ix) => ix,
            _ => panic!(),
        };
        let sel_clt = match c_clt.step(0, &grads).selection.unwrap() {
            Selection::Shared(ix) => ix,
            _ => panic!(),
        };
        let g_true = crate::stats::contraction_coefficient(&y, &sel_true);
        let g_clt = crate::stats::contraction_coefficient(&y, &sel_clt);
        assert!(g_true <= g_clt + 1e-9, "{g_true} vs {g_clt}");
    }

    #[test]
    fn layered_selection_respects_budgets_and_dense_layers() {
        let partition = LayerPartition::from_layers(vec![
            LayerSlice {
                name: "first".into(),
                offset: 0,
                len: 8,
                flops_per_sample: 0.0,
                compress: false, // dense
            },
            LayerSlice {
                name: "rest".into(),
                offset: 8,
                len: 32,
                flops_per_sample: 0.0,
                compress: true,
            },
        ]);
        let ks = vec![8, 4];
        let n = 2;
        let mut c = Coordinator::new(
            n,
            40,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            4,
            fabric(n),
            0,
        )
        .with_layered(partition, ks);
        let mut rng = Rng::new(2);
        let r = c.step(0, &rand_grads(&mut rng, n, 40));
        match r.selection.unwrap() {
            Selection::Shared(idx) => {
                // dense first layer: indices 0..8 all present
                for i in 0..8u32 {
                    assert!(idx.contains(&i));
                }
                assert_eq!(idx.len(), 12); // 8 dense + 4 compressed
            }
            _ => panic!("CLT-k layered must stay shared"),
        }
    }

    #[test]
    fn update_matches_manual_average_on_shared_indices() {
        check("update == masked average of EF grads", 40, |g| {
            let n = g.usize_in(2..=5);
            let dim = g.usize_in(4..=128);
            let k = g.usize_in(1..=dim);
            let grads: Vec<Vec<f32>> = (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
            let mut c = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                1.0,
                k,
                fabric(n),
                0,
            );
            // memory is zero at t=0 → EF grads == grads
            let r = c.step(0, &grads);
            let idx = match r.selection.unwrap() {
                Selection::Shared(ix) => ix,
                _ => panic!(),
            };
            let mut expect = vec![0.0f32; dim];
            for &i in &idx {
                let i = i as usize;
                expect[i] = grads.iter().map(|w| w[i]).sum::<f32>() / n as f32;
            }
            if let Err(i) = allclose(&r.update, &expect, 1e-4, 1e-5) {
                panic!("coord {i}: {} vs {}", r.update[i], expect[i]);
            }
        });
    }
}
