//! Algorithm 1 — the ScaleCom coordination step, decoupled from PJRT.
//!
//! The `Coordinator` owns the per-worker error-feedback memories, the
//! compression scheme, and the fabric; `step` consumes this iteration's
//! stochastic gradients (however they were computed) and produces the
//! averaged update `g^t` plus full per-step diagnostics. The PJRT trainer
//! drives it with real gradients; unit/property tests drive it with
//! synthetic ones.
//!
//! Per Algorithm 1:
//!   line 6: g_i = CLT_{mod(t,n)}(m_i + ∇f_i)        → `select` + sparsify
//!   line 7: m_i ← (1-β)m_i + β(m_i + ∇f_i − g_i)    → EfMemory update
//!   lines 9-11: upload/reduce/download               → Fabric collectives
//!   (warmup steps and uncompressed layers go dense, per §4)
//!
//! ## Execution backends and memory ownership
//!
//! On the `sequential` and `threaded` backends the coordinator holds the
//! memories itself; the pooled backends (`pipelined`, and `socket` —
//! the same pool with its comm lanes over loopback TCP through the wire
//! codec) move them into a persistent worker pool
//! (`runtime::pipelined::WorkerPool`) whose long-lived lanes own them
//! for the whole run. Trainers, hooks, and tests therefore introspect
//! memories through [`Coordinator::memory_snapshot`] — the
//! backend-independent API — instead of a public field.
//!
//! The pooled backends additionally support a **double-buffered**
//! driving mode ([`Coordinator::step_overlapped`]): step t+1's
//! EF-gradient + top-k selection compute runs while step t's collective
//! is still in flight on the comm lanes, which is the compute/comm
//! overlap the paper's scalability story depends on (Remark 3 / §5).

use crate::comm::parallel::LaneTransport;
use crate::comm::{Backend, BucketPlan, CommCost, Fabric, WireCodecConfig};
use crate::compress::{
    sparsify, Compressor, EfMemory, LayerPartition, Selection, SparseGrad,
};
use crate::runtime::bucketed;
use crate::runtime::pipelined::WorkerPool;
use crate::runtime::threaded;
use std::collections::VecDeque;

/// What happened in one coordination step (for metrics + experiments).
pub struct StepResult {
    /// averaged update g^t to feed the optimizer (dense, full dim)
    pub update: Vec<f32>,
    /// index selection used (None during dense warmup)
    pub selection: Option<Selection>,
    /// cyclic leader of this step
    pub leader: usize,
    /// communication cost of the gradient exchange
    pub comm: CommCost,
    /// achieved compression rate this step (dim / transmitted coords)
    pub rate: f64,
    /// whether the dense path was used (warmup / scheme none)
    pub dense: bool,
}

/// Coordination mode.
pub enum Mode {
    /// No compression — dense all-reduce baseline.
    Dense,
    /// Error-feedback sparsification with the given scheme.
    Compressed(Box<dyn Compressor>),
}

/// Where the per-worker error-feedback memories live.
enum Workers {
    /// In the coordinator (sequential + scoped-threaded backends).
    Local(Vec<EfMemory>),
    /// On the persistent worker pool's compute lanes (pipelined +
    /// socket backends).
    Pool(WorkerPool),
}

/// Coordinator liveness as seen by fault-tolerant drivers (the socket
/// node runtime and the simnet elastic mode). `Degraded` means a link
/// fault was detected — a peer died, a link stalled, or a collective
/// mis-framed — and collectives are suspended until the membership
/// re-forms and state is rolled back to a common snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Degraded,
}

/// A step submitted to the pool whose collective has not been waited yet.
struct Pending {
    leader: usize,
    selection: Option<Selection>,
    dense: bool,
}

pub struct Coordinator {
    n: usize,
    dim: usize,
    mode: Mode,
    workers: Workers,
    pub fabric: Fabric,
    /// flat per-step budget: either a single k over the whole vector...
    pub k: usize,
    /// ...or a per-layer budget (paper's FLOPs/gradient rule).
    pub layered: Option<(LayerPartition, Vec<usize>)>,
    /// layer-aligned bucket plan for [`Coordinator::step_bucketed`]
    /// (None / single bucket = monolithic exchange).
    bucket_plan: Option<BucketPlan>,
    /// dense warmup steps (paper: 1-5 epochs uncompressed)
    pub warmup_steps: usize,
    /// execution backend (parity-locked in `rust/tests/backend_parity.rs`)
    backend: Backend,
    /// wire entropy-codec configuration of the socket backend's mesh
    /// (inert on the in-process backends; applied when the socket mesh
    /// is built)
    wire_codec: WireCodecConfig,
    /// hierarchical ring-of-rings group size for the pooled backends'
    /// dense ring collective (0/1 = flat ring; applied when the comm
    /// lanes are built, inert on the lane-free backends)
    group_size: usize,
    /// pipelined steps submitted but not yet waited (≤ 1 in the
    /// double-buffered driving mode)
    pending: VecDeque<Pending>,
    /// eagerly-computed results buffered by `step_overlapped` on the
    /// non-pipelined backends (same observable stream, no lookahead)
    ready: VecDeque<StepResult>,
    /// Set when a pooled collective faulted mid-step: the lanes may
    /// still hold results of other in-flight (bucketed) collectives, so
    /// consuming from them again would hand a later step stale data.
    /// Every subsequent step fails fast instead.
    poisoned: bool,
    /// Fleet liveness: flips to [`Health::Degraded`] on a detected link
    /// fault (alongside `poisoned` for pooled faults, or explicitly via
    /// [`Coordinator::mark_degraded`]); cleared by a successful
    /// [`Coordinator::restore_memories`] rollback or a backend rebuild.
    health: Health,
}

impl Coordinator {
    pub fn new(
        n: usize,
        dim: usize,
        mode: Mode,
        beta: f32,
        k: usize,
        fabric: Fabric,
        warmup_steps: usize,
    ) -> Self {
        assert!(n >= 1 && dim >= 1);
        assert_eq!(fabric.workers(), n, "fabric sized for a different n");
        let memories = (0..n).map(|_| EfMemory::new(dim, beta)).collect();
        Coordinator {
            n,
            dim,
            mode,
            workers: Workers::Local(memories),
            fabric,
            k: k.clamp(1, dim),
            layered: None,
            bucket_plan: None,
            warmup_steps,
            backend: Backend::Sequential,
            wire_codec: WireCodecConfig::default(),
            group_size: 0,
            pending: VecDeque::new(),
            ready: VecDeque::new(),
            poisoned: false,
            health: Health::Healthy,
        }
    }

    pub fn with_layered(mut self, partition: LayerPartition, ks: Vec<usize>) -> Self {
        assert_eq!(partition.total_len(), self.dim);
        assert_eq!(partition.layers.len(), ks.len());
        // An already-installed bucket plan must align with the new
        // partition (the same check set_bucket_plan runs when layered is
        // configured first) — configuration order must not weaken the
        // fail-at-setup guarantee.
        if let Some(plan) = &self.bucket_plan {
            plan.check_aligned(&partition)
                .expect("bucket plan misaligned with the layer partition");
        }
        self.layered = Some((partition, ks));
        self
    }

    /// Install a layer-aligned bucket plan for
    /// [`Coordinator::step_bucketed`]. The plan must tile this
    /// coordinator's gradient dimension; when a layered config is
    /// present the plan must align with its partition (checked here, so
    /// a mismatched `--bucket-bytes`/partition pair fails at setup, not
    /// mid-run).
    pub fn with_buckets(mut self, plan: BucketPlan) -> Self {
        self.set_bucket_plan(Some(plan));
        self
    }

    /// Install or clear the bucket plan (see [`Coordinator::with_buckets`]).
    pub fn set_bucket_plan(&mut self, plan: Option<BucketPlan>) {
        if let Some(p) = &plan {
            assert_eq!(
                p.dim(),
                self.dim,
                "bucket plan tiles a different gradient dimension"
            );
            if let Some((partition, _)) = &self.layered {
                p.check_aligned(partition)
                    .expect("bucket plan misaligned with the layer partition");
            }
        }
        self.bucket_plan = plan;
    }

    pub fn bucket_plan(&self) -> Option<&BucketPlan> {
        self.bucket_plan.as_ref()
    }

    /// Select the execution backend (defaults to `Sequential`). Panics
    /// if the backend's resources cannot be set up — CLI paths should
    /// use [`Coordinator::try_set_backend`] instead.
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.set_backend(backend);
        self
    }

    /// Configure the wire entropy codec of the socket backend's mesh.
    /// Panics if the socket mesh is already built — CLI paths should use
    /// [`Coordinator::try_set_wire_codec`] instead.
    pub fn with_wire_codec(mut self, cfg: WireCodecConfig) -> Self {
        self.try_set_wire_codec(cfg)
            .expect("wire codec must be configured before the socket mesh is built");
        self
    }

    /// Configure the wire entropy codec applied when the socket backend
    /// builds its loopback mesh. Fails if that mesh already exists (the
    /// endpoints latched their codec at construction — rebuilding them
    /// mid-run would tear live lanes down).
    pub fn try_set_wire_codec(&mut self, cfg: WireCodecConfig) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.backend != Backend::Socket || cfg == self.wire_codec,
            "the socket mesh is already built with --wire-compression {}; \
             set the wire codec before selecting the socket backend",
            self.wire_codec.label(),
        );
        self.wire_codec = cfg;
        Ok(())
    }

    pub fn wire_codec(&self) -> WireCodecConfig {
        self.wire_codec
    }

    /// Configure the hierarchical ring-of-rings group size applied when
    /// the pooled backends build their comm lanes (0 = flat ring).
    /// Panics on a bad tiling or a live pool — CLI paths should use
    /// [`Coordinator::try_set_group_size`] instead.
    pub fn with_group_size(mut self, group_size: usize) -> Self {
        self.try_set_group_size(group_size)
            .expect("group size must tile the workers and be set before the lanes are built");
        self
    }

    /// Configure the hierarchical group size of the pooled backends'
    /// dense ring collective. Fails on a tiling the shared validator
    /// rejects, or if the lanes are already built with a different
    /// topology (they latched it at construction — rebuilding them
    /// mid-run would tear live collectives down).
    pub fn try_set_group_size(&mut self, group_size: usize) -> anyhow::Result<()> {
        crate::comm::parallel::validate_group_size(self.n, group_size)?;
        anyhow::ensure!(
            !self.backend.is_pooled() || group_size == self.group_size,
            "the comm lanes are already built with --group-size {}; set the \
             group size before selecting a pooled backend",
            self.group_size,
        );
        self.group_size = group_size;
        Ok(())
    }

    pub fn group_size(&self) -> usize {
        self.group_size
    }

    /// Infallible [`Coordinator::try_set_backend`] for contexts that
    /// treat a failed mesh setup as a bug (tests, benches).
    pub fn set_backend(&mut self, backend: Backend) {
        self.try_set_backend(backend)
            .expect("backend switch (socket backend binds a loopback TCP mesh)");
    }

    /// Switch execution backend, migrating the per-worker memories
    /// between the coordinator and the persistent pool. Must not be
    /// called with overlapped steps in flight. Fails — instead of
    /// panicking — when the socket backend cannot build its loopback
    /// mesh (fd limits, ephemeral-port exhaustion), so launcher code can
    /// surface a clean error.
    pub fn try_set_backend(&mut self, backend: Backend) -> anyhow::Result<()> {
        assert!(
            !self.in_flight(),
            "cannot switch backends with steps in flight"
        );
        if self.backend == backend {
            return Ok(());
        }
        // Build the fallible part (the lanes/mesh) BEFORE moving the
        // memories, so a failure leaves the coordinator fully usable on
        // its current backend. Both pooled backends honor the
        // hierarchical group size (0 = flat ring).
        let pooled_lanes = match backend {
            Backend::Socket => Some(crate::comm::parallel::CommLanes::with_topology(
                self.n,
                LaneTransport::Socket(self.wire_codec),
                self.group_size,
            )?),
            Backend::Pipelined => Some(crate::comm::parallel::CommLanes::with_topology(
                self.n,
                LaneTransport::Channel,
                self.group_size,
            )?),
            Backend::Sequential | Backend::Threaded => None,
        };
        let memories =
            match std::mem::replace(&mut self.workers, Workers::Local(Vec::new())) {
                Workers::Local(m) => m,
                // Snapshot out of the pool, then drop it (joins lanes).
                Workers::Pool(pool) => pool.snapshot(),
            };
        self.workers = match backend {
            Backend::Pipelined | Backend::Socket => Workers::Pool(WorkerPool::with_lanes(
                memories,
                pooled_lanes.expect("pooled lanes built above"),
            )),
            Backend::Sequential | Backend::Threaded => Workers::Local(memories),
        };
        self.backend = backend;
        // The switch tore the old lanes down and built fresh ones (or
        // left lane-free local workers) — any earlier fault poisoning no
        // longer describes live state.
        self.poisoned = false;
        self.health = Health::Healthy;
        Ok(())
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    pub fn workers(&self) -> usize {
        self.n
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// True when `step_overlapped` has a step in flight (or buffered)
    /// that `finish_overlapped` has not drained yet.
    pub fn in_flight(&self) -> bool {
        !self.pending.is_empty() || !self.ready.is_empty()
    }

    fn pool(&self) -> &WorkerPool {
        match &self.workers {
            Workers::Pool(p) => p,
            Workers::Local(_) => panic!("pooled backend without a worker pool"),
        }
    }

    /// Direct borrow of the error-feedback memories. Only the in-process
    /// backends keep them in the coordinator — on the pooled backends
    /// (`pipelined`/`socket`) they live on the worker pool; use
    /// [`Coordinator::memory_snapshot`] there.
    pub fn memories(&self) -> &[EfMemory] {
        match &self.workers {
            Workers::Local(m) => m,
            Workers::Pool(_) => panic!(
                "pooled-backend memories live on the worker pool; use memory_snapshot()"
            ),
        }
    }

    /// Mutable counterpart of [`Coordinator::memories`] (kernel path,
    /// sequential backend only).
    pub fn memories_mut(&mut self) -> &mut [EfMemory] {
        match &mut self.workers {
            Workers::Local(m) => m,
            Workers::Pool(_) => panic!(
                "pooled-backend memories live on the worker pool; use memory_snapshot()"
            ),
        }
    }

    /// Backend-independent snapshot of every worker's error-feedback
    /// memory. On the pipelined backend this is served by the pool's
    /// lanes in FIFO order, so it reflects every step submitted so far —
    /// including ones whose collective is still in flight (their memory
    /// update never depends on the reduced values).
    pub fn memory_snapshot(&self) -> Vec<EfMemory> {
        match &self.workers {
            Workers::Local(m) => m.clone(),
            Workers::Pool(p) => p.snapshot(),
        }
    }

    pub fn set_beta(&mut self, beta: f32) {
        match &mut self.workers {
            Workers::Local(ms) => {
                for m in ms {
                    m.set_beta(beta);
                }
            }
            Workers::Pool(p) => p.set_beta(beta),
        }
    }

    /// Current fleet liveness (see [`Health`]).
    pub fn health(&self) -> Health {
        self.health
    }

    /// Record an externally-detected link fault (heartbeat timeout, a
    /// peer's EOF, a failed rendezvous): collectives should not be driven
    /// again until state is rolled back via
    /// [`Coordinator::restore_memories`] or the backend is rebuilt.
    pub fn mark_degraded(&mut self) {
        self.health = Health::Degraded;
    }

    /// Roll every worker's error-feedback memory back to a snapshot taken
    /// with [`Coordinator::memory_snapshot`] — the recovery half of the
    /// reconnect-with-resume contract: after membership re-forms, all
    /// ranks restore the snapshot of the last globally-completed step and
    /// replay forward, reproducing the fault-free selections bit-exactly.
    ///
    /// Only the lane-free backends (sequential/threaded) support in-place
    /// restore; the pooled backends' memories live on worker lanes whose
    /// in-flight state cannot be rewritten — rebuild the coordinator (or
    /// switch backends, which re-seeds the pool from a snapshot) instead.
    /// A successful restore clears the [`Health::Degraded`] flag.
    pub fn restore_memories(&mut self, memories: Vec<EfMemory>) -> anyhow::Result<()> {
        anyhow::ensure!(
            memories.len() == self.n,
            "restore_memories: snapshot holds {} workers, coordinator has {}",
            memories.len(),
            self.n
        );
        for (w, m) in memories.iter().enumerate() {
            anyhow::ensure!(
                m.dim() == self.dim,
                "restore_memories: worker {w} snapshot dim {} != coordinator dim {}",
                m.dim(),
                self.dim
            );
        }
        match &mut self.workers {
            Workers::Local(ms) => {
                *ms = memories;
                self.health = Health::Healthy;
                Ok(())
            }
            Workers::Pool(_) => anyhow::bail!(
                "restore_memories: pooled backends keep memories on worker \
                 lanes and cannot restore in place — rebuild the coordinator \
                 from the snapshot (or try_set_backend to re-seed the pool)"
            ),
        }
    }

    /// Error-feedback gradients m_i + ∇f_i for all workers.
    pub fn ef_grads(&self, grads: &[Vec<f32>]) -> Vec<Vec<f32>> {
        assert_eq!(grads.len(), self.n);
        match &self.workers {
            Workers::Local(ms) => {
                ms.iter().zip(grads).map(|(m, g)| m.ef_grad(g)).collect()
            }
            Workers::Pool(p) => p.ef_grads(grads),
        }
    }

    fn validate_grads(&self, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), self.n, "need one gradient per worker");
        for (w, g) in grads.iter().enumerate() {
            assert_eq!(g.len(), self.dim, "worker {w} gradient dim");
        }
    }

    /// One coordination step over this iteration's stochastic gradients.
    /// A lane fault on the socket transport (dead, wedged, or mis-framed
    /// peer) surfaces as an `anyhow` error — launcher paths (`train
    /// --backend socket`) report it cleanly instead of panicking.
    pub fn try_step(&mut self, t: usize, grads: &[Vec<f32>]) -> anyhow::Result<StepResult> {
        assert!(
            !self.in_flight(),
            "step() with overlapped steps in flight; drain finish_overlapped() first"
        );
        self.ensure_healthy()?;
        if self.backend.is_pooled() {
            self.submit(t, grads);
            let r = self.wait_oldest()?;
            Ok(r.expect("step was just submitted"))
        } else {
            Ok(self.step_eager(t, grads))
        }
    }

    /// Fail fast after a mid-step collective fault: the lanes may still
    /// carry other in-flight collectives' (bucket-tagged) results, and
    /// consuming them for a new step would silently corrupt it.
    fn ensure_healthy(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.poisoned,
            "coordinator poisoned by an earlier collective fault — lane state \
             is unrecoverable; rebuild the coordinator (or restart the run)"
        );
        Ok(())
    }

    /// Infallible [`Coordinator::try_step`] for tests/benches, where a
    /// lane fault on the in-process mesh means the host itself is broken
    /// and a loud panic is the right outcome.
    pub fn step(&mut self, t: usize, grads: &[Vec<f32>]) -> StepResult {
        self.try_step(t, grads).expect("coordination step failed")
    }

    /// Double-buffered driving mode: submit step `t`, then return step
    /// `t−1`'s result (None on the first call). On the pooled backends
    /// (pipelined/socket) step t's EF-gradient/selection compute and
    /// memory updates overlap step t−1's in-flight collective; the other
    /// backends execute eagerly and just delay the result by one call,
    /// so every backend produces the identical stream (the backend-matrix
    /// parity lock). Call [`Coordinator::finish_overlapped`] to drain the
    /// last step. Faults propagate like [`Coordinator::try_step`].
    pub fn try_step_overlapped(
        &mut self,
        t: usize,
        grads: &[Vec<f32>],
    ) -> anyhow::Result<Option<StepResult>> {
        // The two driving modes are exclusive, loudly: the per-bucket
        // scheduler (`--bucket-bytes`) owns the comm lanes *within* a
        // step, while the double-buffered lookahead keeps a whole step's
        // collective in flight *across* steps — composing them would
        // interleave bucket-tagged and monolithic results on the same
        // lanes. (ROADMAP "cross-step composition" follow-up.)
        anyhow::ensure!(
            self.bucket_plan.as_ref().map_or(true, |p| p.is_single()),
            "the bucketed exchange (--bucket-bytes > 0) cannot be combined \
             with the double-buffered step_overlapped driving mode; drop \
             --bucket-bytes to stream steps, or drive the coordinator with \
             step()/step_bucketed()"
        );
        self.ensure_healthy()?;
        if self.backend.is_pooled() {
            self.submit(t, grads);
            if self.pending.len() > 1 {
                self.wait_oldest()
            } else {
                Ok(None)
            }
        } else {
            let r = self.step_eager(t, grads);
            self.ready.push_back(r);
            if self.ready.len() > 1 {
                Ok(self.ready.pop_front())
            } else {
                Ok(None)
            }
        }
    }

    /// Infallible [`Coordinator::try_step_overlapped`] (tests/benches).
    pub fn step_overlapped(&mut self, t: usize, grads: &[Vec<f32>]) -> Option<StepResult> {
        self.try_step_overlapped(t, grads)
            .expect("overlapped coordination step failed")
    }

    /// Drain every step still in flight (or buffered), in step order.
    /// On a lane fault the remaining in-flight steps are lost (the
    /// stream is mis-framed beyond recovery) and the error is returned.
    pub fn try_finish_overlapped(&mut self) -> anyhow::Result<Vec<StepResult>> {
        let mut out: Vec<StepResult> = self.ready.drain(..).collect();
        while let Some(r) = self.wait_oldest()? {
            out.push(r);
        }
        Ok(out)
    }

    /// Infallible [`Coordinator::try_finish_overlapped`] (tests/benches).
    pub fn finish_overlapped(&mut self) -> Vec<StepResult> {
        self.try_finish_overlapped()
            .expect("overlapped drain failed")
    }

    /// One coordination step driven **per bucket** (the compute/comm
    /// overlap the trainer runs on): walk the bucket plan in backward
    /// order — mirroring backprop, which finishes the last layers'
    /// gradients first — and on the pooled backends submit bucket b's
    /// collective to the comm lanes as soon as its EF-gradient/CLT-k
    /// selection is done, so it is in flight while bucket b−1's
    /// selection computes; completed buckets are then applied into the
    /// dense update in the same order as each lands. The in-process
    /// backends execute the identical per-bucket schedule eagerly, so
    /// all four backends produce the same observable stream (the
    /// bucketed axis of `rust/tests/backend_parity.rs`).
    ///
    /// Requires a layered config (`with_layered`): buckets are
    /// layer-aligned, and because every compressor's selection is a pure
    /// function of `(step, layer views, k)`, per-bucket selection over
    /// the bucket's layer span reproduces the monolithic layered
    /// selection exactly. Without a multi-bucket plan — or on the dense
    /// path (warmup / `Mode::Dense`) — this delegates to
    /// [`Coordinator::try_step`].
    ///
    /// ## Comm accounting vs the monolithic step
    ///
    /// Selections, values, and per-worker rates match the monolithic
    /// step, but the ledger is **per bucket** (one `record_*` entry per
    /// bucket, aggregated into an `op = "bucketed_exchange"` total), and
    /// each bucket picks its own exchange kind: a bucket whose layers
    /// all stayed shared — e.g. a dense-exempt layer alone in its bucket
    /// under a non-commutative scheme — rides the cheap commutative ring
    /// reduce, where the monolithic step would have dragged those
    /// coordinates into the one big gather. That is a deliberate
    /// improvement bucketing unlocks (locked by
    /// `mixed_kind_buckets_split_the_exchange_by_bucket`), not drift:
    /// exact byte parity with the monolithic gather is unattainable
    /// anyway (its `up` is a max over whole-vector contributions, which
    /// no per-bucket sum reproduces). Across backends the per-bucket
    /// ledger is exact, per the parity matrix.
    pub fn try_step_bucketed(&mut self, t: usize, grads: &[Vec<f32>]) -> anyhow::Result<StepResult> {
        assert!(
            !self.in_flight(),
            "step_bucketed() with overlapped steps in flight; drain finish_overlapped() first"
        );
        let multi = self.bucket_plan.as_ref().map_or(false, |p| !p.is_single());
        let dense_path = matches!(self.mode, Mode::Dense) || t < self.warmup_steps;
        if !multi || dense_path {
            return self.try_step(t, grads);
        }
        self.ensure_healthy()?;
        self.validate_grads(grads);
        anyhow::ensure!(
            self.layered.is_some(),
            "bucketed exchange needs per-layer budgets: configure the coordinator \
             with with_layered (buckets are layer-aligned, so selection must \
             decompose per layer to stay exact)"
        );
        let plan = self.bucket_plan.clone().expect("multi-bucket plan checked above");
        // A fault below leaves other in-flight buckets' results queued on
        // the lanes — poison the coordinator so no later step consumes
        // them as its own.
        let r = self.run_bucketed(t, grads, plan);
        if r.is_err() {
            self.poisoned = true;
            self.health = Health::Degraded;
        } else {
            self.refresh_codec_stats();
        }
        r
    }

    /// The multi-bucket driver behind [`Coordinator::try_step_bucketed`]
    /// (which owns the delegation, config checks, and fault poisoning).
    fn run_bucketed(
        &mut self,
        t: usize,
        grads: &[Vec<f32>],
        plan: BucketPlan,
    ) -> anyhow::Result<StepResult> {
        let leader = t % self.n;
        let n = self.n;
        let dim = self.dim;
        let backend = self.backend;
        let threads = self.scan_threads();
        let order = bucketed::backward_order(&plan);
        let nb = plan.num_buckets();
        let mut selections: Vec<Option<Selection>> = (0..nb).map(|_| None).collect();
        let mut update = vec![0.0f32; dim];
        let mut costs: Vec<CommCost> = Vec::with_capacity(nb);

        // Disjoint field borrows: the compressor (self.mode), the layered
        // config (self.layered), the workers, and the fabric are used
        // side by side below — all direct field accesses, never whole-self
        // method calls.
        let (partition, ks) = self.layered.as_ref().expect("ensured above");
        let compressor = match &mut self.mode {
            Mode::Compressed(c) => c.as_mut(),
            Mode::Dense => unreachable!("dense path handled above"),
        };
        match &mut self.workers {
            Workers::Pool(pool) => {
                // Submission sweep: bucket b's collective goes onto the
                // lanes before bucket b−1's selection starts computing.
                for &b in &order {
                    let bucket = *plan.bucket(b);
                    let (sub_partition, sub_ks) = plan.bucket_config(b, partition, ks);
                    let efs = {
                        let _sp = crate::obs::span(crate::obs::Category::EfUpdate)
                            .step(t as u32)
                            .bucket(b as u32);
                        let slices: Vec<Vec<f32>> =
                            grads.iter().map(|g| g[bucket.range()].to_vec()).collect();
                        pool.begin_bucket(b as u32, bucket.offset, slices)
                    };
                    let ef_views: Vec<&[f32]> = efs.iter().map(|e| e.as_slice()).collect();
                    let sel = {
                        let _sp = crate::obs::span(crate::obs::Category::Select)
                            .step(t as u32)
                            .bucket(b as u32);
                        select_layered(compressor, t, &ef_views, &sub_partition, &sub_ks, threads)
                    };
                    let _sp = crate::obs::span(crate::obs::Category::Encode)
                        .step(t as u32)
                        .bucket(b as u32);
                    match &sel {
                        Selection::Shared(idx) => {
                            let vals: Vec<Vec<f32>> = efs
                                .iter()
                                .map(|ef| idx.iter().map(|&i| ef[i as usize]).collect())
                                .collect();
                            pool.finish_shared_bucket(b as u32, idx, vals);
                        }
                        Selection::PerWorker(per) => {
                            let sparses: Vec<SparseGrad> = efs
                                .iter()
                                .zip(per)
                                .map(|(ef, idx)| sparsify(ef, idx))
                                .collect();
                            pool.finish_gather_bucket(b as u32, sparses);
                        }
                    }
                    drop(_sp);
                    selections[b] = Some(sel);
                }
                // Completion sweep: lanes complete FIFO, so buckets land
                // in submission order; each is applied as it arrives.
                for &b in &order {
                    let bucket = *plan.bucket(b);
                    let _sp = crate::obs::span(crate::obs::Category::Collective)
                        .step(t as u32)
                        .bucket(b as u32);
                    match selections[b].as_ref().expect("submitted above") {
                        Selection::Shared(idx) => {
                            let (tag, vals) = pool.try_wait_reduced()?;
                            anyhow::ensure!(
                                tag == b as u32,
                                "bucket results out of order: waiting on bucket {b}, got {tag}"
                            );
                            for (&i, &v) in idx.iter().zip(&vals) {
                                update[bucket.offset + i as usize] = v;
                            }
                            costs.push(self.fabric.record_sparse_allreduce_shared(n, idx.len()));
                        }
                        Selection::PerWorker(_) => {
                            let (tag, avg_local, gs) = pool.try_wait_gathered()?;
                            anyhow::ensure!(
                                tag == b as u32,
                                "bucket results out of order: waiting on bucket {b}, got {tag}"
                            );
                            update[bucket.range()].copy_from_slice(&avg_local);
                            costs.push(self.fabric.record_sparse_gather(&gs));
                        }
                    }
                }
            }
            Workers::Local(memories) => {
                // Eager per-bucket schedule in the identical order — the
                // parity reference (sequential) and the scoped-thread
                // engine (threaded: real ring collective per bucket).
                for &b in &order {
                    let bucket = *plan.bucket(b);
                    let (sub_partition, sub_ks) = plan.bucket_config(b, partition, ks);
                    let efs: Vec<Vec<f32>> = memories
                        .iter()
                        .zip(grads)
                        .map(|(m, g)| m.ef_grad_range(bucket.offset, &g[bucket.range()]))
                        .collect();
                    let ef_views: Vec<&[f32]> = efs.iter().map(|e| e.as_slice()).collect();
                    let sel =
                        select_layered(compressor, t, &ef_views, &sub_partition, &sub_ks, threads);
                    match &sel {
                        Selection::Shared(idx) => {
                            let reduced = match backend {
                                // the fabric's own shared reduce — ONE
                                // definition of the worker-order
                                // arithmetic and its cost booking
                                Backend::Sequential => {
                                    let sparses: Vec<SparseGrad> =
                                        efs.iter().map(|ef| sparsify(ef, idx)).collect();
                                    self.fabric.sparse_allreduce_shared(&sparses, leader).values
                                }
                                // real channel-ring collective on scoped
                                // worker threads, identical cost booking
                                Backend::Threaded => {
                                    let vals: Vec<Vec<f32>> = efs
                                        .iter()
                                        .map(|ef| {
                                            idx.iter().map(|&i| ef[i as usize]).collect()
                                        })
                                        .collect();
                                    let out = threaded::dense_allreduce_avg(&vals);
                                    self.fabric.record_sparse_allreduce_shared(n, idx.len());
                                    out
                                }
                                Backend::Pipelined | Backend::Socket => {
                                    unreachable!("pooled backends take the Pool arm")
                                }
                            };
                            for (&i, &v) in idx.iter().zip(&reduced) {
                                update[bucket.offset + i as usize] = v;
                            }
                            costs.push(self.fabric.stats().last_cost().clone());
                        }
                        Selection::PerWorker(per) => {
                            let sparses: Vec<SparseGrad> = efs
                                .iter()
                                .zip(per)
                                .map(|(ef, idx)| sparsify(ef, idx))
                                .collect();
                            // the shared worker-order gather reduction —
                            // bit-identical on every backend
                            let (avg_local, gs) =
                                crate::comm::fabric::reduce_gathered(&sparses, bucket.len);
                            update[bucket.range()].copy_from_slice(&avg_local);
                            costs.push(self.fabric.record_sparse_gather(&gs));
                        }
                    }
                    // slice memory update (Eqn. 5) with each worker's
                    // bucket-local transmitted indices
                    for (w, (mem, g)) in memories.iter_mut().zip(grads).enumerate() {
                        mem.update_after_send_range(
                            bucket.offset,
                            &g[bucket.range()],
                            sel.indices_for(w),
                        );
                    }
                    selections[b] = Some(sel);
                }
            }
        }

        let per_bucket: Vec<Selection> = selections
            .into_iter()
            .map(|s| s.expect("every bucket selected"))
            .collect();
        let merged = bucketed::merge_selections(&plan, &per_bucket, n);
        let sent = bucketed::sent_coords(&merged);
        Ok(StepResult {
            update,
            rate: dim as f64 / sent.max(1) as f64,
            selection: Some(merged),
            leader,
            comm: bucketed::aggregate_comm(&costs),
            dense: false,
        })
    }

    /// Infallible [`Coordinator::try_step_bucketed`] (tests/benches).
    pub fn step_bucketed(&mut self, t: usize, grads: &[Vec<f32>]) -> StepResult {
        self.try_step_bucketed(t, grads)
            .expect("bucketed coordination step failed")
    }

    /// Submit one step to the worker pool without waiting for its
    /// collective: EF gradients + stash on the compute lanes, selection
    /// on the calling thread, payload forwarded to the comm lanes,
    /// memory updates applied lane-side.
    fn submit(&mut self, t: usize, grads: &[Vec<f32>]) {
        self.validate_grads(grads);
        let leader = t % self.n;
        let dense_path = matches!(self.mode, Mode::Dense) || t < self.warmup_steps;
        if dense_path {
            self.pool().dense_step(grads);
            self.pending.push_back(Pending {
                leader,
                selection: None,
                dense: true,
            });
            return;
        }
        let efs = {
            let _sp = crate::obs::span(crate::obs::Category::EfUpdate).step(t as u32);
            self.pool().begin_step(grads)
        };
        let selection = {
            let _sp = crate::obs::span(crate::obs::Category::Select).step(t as u32);
            self.select_indices(t, &efs)
        };
        let _sp = crate::obs::span(crate::obs::Category::Encode).step(t as u32);
        match &selection {
            Selection::Shared(idx) => {
                let vals: Vec<Vec<f32>> = efs
                    .iter()
                    .map(|ef| idx.iter().map(|&i| ef[i as usize]).collect())
                    .collect();
                self.pool().finish_shared(idx, vals);
            }
            Selection::PerWorker(per) => {
                let sparses: Vec<SparseGrad> = efs
                    .iter()
                    .zip(per)
                    .map(|(ef, idx)| sparsify(ef, idx))
                    .collect();
                self.pool().finish_gather(sparses);
            }
        }
        drop(_sp);
        self.pending.push_back(Pending {
            leader,
            selection: Some(selection),
            dense: false,
        });
    }

    /// Wait for the oldest submitted step's collective, book its
    /// communication cost (identical shape accounting to the other
    /// backends), and assemble the `StepResult`. On a lane fault the
    /// remaining pending steps are dropped (the stream is mis-framed
    /// beyond recovery) and the error propagates.
    fn wait_oldest(&mut self) -> anyhow::Result<Option<StepResult>> {
        let Some(p) = self.pending.pop_front() else {
            return Ok(None);
        };
        let r = self.wait_pending(p);
        if r.is_err() {
            self.pending.clear();
            self.poisoned = true;
            self.health = Health::Degraded;
        } else {
            self.refresh_codec_stats();
        }
        r.map(Some)
    }

    /// Pull the socket mesh's entropy-codec counters into the fabric's
    /// stats (all-zero on the channel-transport and lane-free backends).
    fn refresh_codec_stats(&mut self) {
        if let Workers::Pool(p) = &self.workers {
            self.fabric.update_codec_stats(p.codec_snapshot());
            self.fabric
                .update_rtt_stats(crate::comm::socket::rtt_snapshot());
        }
    }

    fn wait_pending(&mut self, p: Pending) -> anyhow::Result<StepResult> {
        let _sp = crate::obs::span(crate::obs::Category::Collective);
        if p.dense {
            let (bucket, update) = self.pool().try_wait_reduced()?;
            debug_assert_eq!(bucket, 0, "monolithic steps carry bucket 0");
            self.fabric.record_dense_allreduce(self.n, self.dim);
            let comm = self.fabric.stats().last_cost().clone();
            return Ok(StepResult {
                update,
                selection: None,
                leader: p.leader,
                comm,
                rate: 1.0,
                dense: true,
            });
        }
        let selection = p.selection.expect("compressed step carries a selection");
        let (update, comm, sent) = match &selection {
            Selection::Shared(idx) => {
                let (bucket, vals) = self.pool().try_wait_reduced()?;
                debug_assert_eq!(bucket, 0, "monolithic steps carry bucket 0");
                let comm = self.fabric.record_sparse_allreduce_shared(self.n, idx.len());
                let avg = SparseGrad::new(self.dim, idx.clone(), vals);
                (avg.to_dense(), comm, idx.len())
            }
            Selection::PerWorker(per) => {
                let (bucket, avg, gs) = self.pool().try_wait_gathered()?;
                debug_assert_eq!(bucket, 0, "monolithic steps carry bucket 0");
                let comm = self.fabric.record_sparse_gather(&gs);
                let sent = per.iter().map(|p| p.len()).max().unwrap_or(0);
                (avg, comm, sent)
            }
        };
        Ok(StepResult {
            update,
            rate: self.dim as f64 / sent.max(1) as f64,
            selection: Some(selection),
            leader: p.leader,
            comm,
            dense: false,
        })
    }

    /// Selection fan-out follows the machine, not the simulated worker
    /// count: 64 simulated workers on a 4-core box must not spawn 64
    /// scan threads (results are thread-count-independent by the
    /// `select_parallel` contract). One rule for both the monolithic
    /// (`select_indices`) and bucketed (`try_step_bucketed`) drivers.
    fn scan_threads(&self) -> usize {
        match self.backend {
            Backend::Sequential => 1,
            Backend::Threaded | Backend::Pipelined | Backend::Socket => {
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1)
            }
        }
    }

    /// Run the compression scheme over this step's EF gradients (the
    /// selection compute the pipelined backend overlaps with the
    /// previous step's collective).
    fn select_indices(&mut self, t: usize, efs: &[Vec<f32>]) -> Selection {
        let ef_views: Vec<&[f32]> = efs.iter().map(|e| e.as_slice()).collect();
        let threads = self.scan_threads();
        let compressor = match &mut self.mode {
            Mode::Compressed(c) => c,
            Mode::Dense => unreachable!("selection on the dense path"),
        };
        if let Some((partition, ks)) = &self.layered {
            select_layered(compressor.as_mut(), t, &ef_views, partition, ks, threads)
        } else if threads > 1 {
            compressor.select_parallel(t, &ef_views, self.k, threads)
        } else {
            compressor.select(t, &ef_views, self.k)
        }
    }

    /// Synchronous step on the in-process backends (the PR 1 semantics).
    fn step_eager(&mut self, t: usize, grads: &[Vec<f32>]) -> StepResult {
        self.validate_grads(grads);
        let leader = t % self.n;

        let dense_path = matches!(self.mode, Mode::Dense) || t < self.warmup_steps;
        if dense_path {
            let update = match self.backend {
                Backend::Sequential => self.fabric.dense_allreduce_avg(grads),
                Backend::Threaded => {
                    let out = threaded::dense_allreduce_avg(grads);
                    self.fabric.record_dense_allreduce(grads.len(), self.dim);
                    out
                }
                Backend::Pipelined | Backend::Socket => {
                    unreachable!("pooled-backend steps go through submit")
                }
            };
            let comm = self.fabric.stats().last_cost().clone();
            return StepResult {
                update,
                selection: None,
                leader,
                comm,
                rate: 1.0,
                dense: true,
            };
        }

        // --- compressed path -------------------------------------------
        let efs = {
            let _sp = crate::obs::span(crate::obs::Category::EfUpdate).step(t as u32);
            match self.backend {
                Backend::Sequential => self.ef_grads(grads),
                Backend::Threaded => threaded::parallel_ef_grads(self.memories(), grads),
                Backend::Pipelined | Backend::Socket => {
                    unreachable!("pooled-backend steps go through submit")
                }
            }
        };
        let backend = self.backend;
        let n = self.n;
        let selection = {
            let _sp = crate::obs::span(crate::obs::Category::Select).step(t as u32);
            self.select_indices(t, &efs)
        };

        let _sp = crate::obs::span(crate::obs::Category::Collective).step(t as u32);
        let (update, comm, sent) = match (&selection, backend) {
            (Selection::Shared(idx), Backend::Sequential) => {
                let sparses: Vec<SparseGrad> =
                    efs.iter().map(|ef| sparsify(ef, idx)).collect();
                let avg = self.fabric.sparse_allreduce_shared(&sparses, leader);
                (
                    avg.to_dense(),
                    self.fabric.stats().last_cost().clone(),
                    idx.len(),
                )
            }
            (Selection::Shared(idx), Backend::Threaded) => {
                // sparsify + ring reduce + memory update on worker threads
                let vals = threaded::exchange_shared(
                    self.local_memories_mut(),
                    grads,
                    &efs,
                    idx,
                );
                let comm = self.fabric.record_sparse_allreduce_shared(n, idx.len());
                let avg = SparseGrad::new(self.dim, idx.clone(), vals);
                (avg.to_dense(), comm, idx.len())
            }
            (Selection::PerWorker(per), Backend::Sequential) => {
                let sparses: Vec<SparseGrad> = efs
                    .iter()
                    .zip(per)
                    .map(|(ef, idx)| sparsify(ef, idx))
                    .collect();
                let avg = self.fabric.sparse_gather_avg(&sparses);
                let sent = per.iter().map(|p| p.len()).max().unwrap_or(0);
                (avg, self.fabric.stats().last_cost().clone(), sent)
            }
            (Selection::PerWorker(per), Backend::Threaded) => {
                // sparsify + star gather + memory update on worker threads
                let (avg, gs) = threaded::exchange_gather(
                    self.local_memories_mut(),
                    grads,
                    &efs,
                    per,
                );
                let comm = self.fabric.record_sparse_gather(&gs);
                let sent = per.iter().map(|p| p.len()).max().unwrap_or(0);
                (avg, comm, sent)
            }
            (_, Backend::Pipelined | Backend::Socket) => {
                unreachable!("pooled-backend steps go through submit")
            }
        };
        drop(_sp);

        // memory update (Eqn. 5) with each worker's transmitted indices —
        // the threaded exchanges already updated each memory on its
        // worker's thread.
        if backend == Backend::Sequential {
            let memories = self.local_memories_mut();
            for (w, mem) in memories.iter_mut().enumerate() {
                mem.update_after_send(&grads[w], selection.indices_for(w));
            }
        }

        StepResult {
            update,
            rate: self.dim as f64 / sent.max(1) as f64,
            selection: Some(selection),
            leader,
            comm,
            dense: false,
        }
    }

    fn local_memories_mut(&mut self) -> &mut Vec<EfMemory> {
        match &mut self.workers {
            Workers::Local(m) => m,
            Workers::Pool(_) => {
                unreachable!("in-process step on a pooled backend")
            }
        }
    }
}

/// Apply a compressor independently per layer slice with per-layer k,
/// concatenating the global index sets (the §4 per-layer rate rule).
/// `threads > 1` routes each layer's scan through `select_parallel`
/// (identical output — the parity contract), so the threaded backend's
/// selection speedup also applies to flops-rule configs.
pub fn select_layered(
    compressor: &mut dyn Compressor,
    t: usize,
    efs: &[&[f32]],
    partition: &LayerPartition,
    ks: &[usize],
    threads: usize,
) -> Selection {
    let n = efs.len();
    let mut shared: Vec<u32> = Vec::new();
    let mut per_worker: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut any_per_worker = false;
    for (layer, &k) in partition.layers.iter().zip(ks) {
        let views: Vec<&[f32]> = efs
            .iter()
            .map(|ef| &ef[layer.offset..layer.offset + layer.len])
            .collect();
        let sel = if !layer.compress || k >= layer.len {
            // dense layer: every coordinate selected
            Selection::Shared((0..layer.len as u32).collect())
        } else if threads > 1 {
            compressor.select_parallel(t, &views, k, threads)
        } else {
            compressor.select(t, &views, k)
        };
        match sel {
            Selection::Shared(idx) => {
                let off = layer.offset as u32;
                shared.extend(idx.iter().map(|&i| i + off));
                for pw in &mut per_worker {
                    pw.extend(idx.iter().map(|&i| i + off));
                }
            }
            Selection::PerWorker(per) => {
                any_per_worker = true;
                let off = layer.offset as u32;
                for (w, idx) in per.iter().enumerate() {
                    per_worker[w].extend(idx.iter().map(|&i| i + off));
                }
            }
        }
    }
    if any_per_worker {
        Selection::PerWorker(per_worker)
    } else {
        Selection::Shared(shared)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{FabricConfig, Topology};
    use crate::compress::rate::LayerSlice;
    use crate::compress::schemes::{CltK, LocalTopK, TrueTopK};
    use crate::proptest::check;
    use crate::util::floats::allclose;
    use crate::util::rng::Rng;

    fn fabric(n: usize) -> Fabric {
        Fabric::new(FabricConfig {
            workers: n,
            topology: Topology::ParameterServer,
            ..FabricConfig::default()
        })
    }

    fn rand_grads(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| {
                let mut v = vec![0.0; dim];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn dense_mode_averages_exactly() {
        let mut c = Coordinator::new(2, 3, Mode::Dense, 1.0, 3, fabric(2), 0);
        let r = c.step(0, &[vec![1.0, 2.0, 3.0], vec![3.0, 2.0, 1.0]]);
        assert_eq!(r.update, vec![2.0, 2.0, 2.0]);
        assert!(r.dense);
        assert_eq!(r.rate, 1.0);
        assert!(r.selection.is_none());
    }

    #[test]
    fn warmup_steps_go_dense_then_compress() {
        let mut c = Coordinator::new(
            2,
            10,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            2,
            fabric(2),
            3,
        );
        let mut rng = Rng::new(5);
        for t in 0..5 {
            let r = c.step(t, &rand_grads(&mut rng, 2, 10));
            assert_eq!(r.dense, t < 3, "step {t}");
        }
    }

    #[test]
    fn clt_k_leader_cycles() {
        let n = 3;
        let mut c = Coordinator::new(
            n,
            12,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            2,
            fabric(n),
            0,
        );
        let mut rng = Rng::new(7);
        for t in 0..6 {
            let r = c.step(t, &rand_grads(&mut rng, n, 12));
            assert_eq!(r.leader, t % n);
            assert!(matches!(r.selection, Some(Selection::Shared(_))));
            assert_eq!(r.rate, 6.0);
        }
    }

    #[test]
    fn error_feedback_no_information_lost_beta1() {
        // Invariant: with β=1, sum over steps of updates + final averaged
        // memory == running average of all raw gradients, coordinate-wise.
        check("EF conservation over trajectory", 25, |g| {
            let n = g.usize_in(2..=4);
            let dim = g.usize_in(4..=64);
            let k = g.usize_in(1..=dim);
            let steps = g.usize_in(1..=10);
            let mut c = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                1.0,
                k,
                fabric(n),
                0,
            );
            let mut total_grads = vec![0.0f64; dim];
            let mut total_updates = vec![0.0f64; dim];
            for t in 0..steps {
                let grads: Vec<Vec<f32>> =
                    (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
                for w in &grads {
                    for (acc, &v) in total_grads.iter_mut().zip(w) {
                        *acc += v as f64 / n as f64;
                    }
                }
                let r = c.step(t, &grads);
                for (acc, &v) in total_updates.iter_mut().zip(&r.update) {
                    *acc += v as f64;
                }
            }
            // add back what's still in memory (averaged over workers)
            for mem in &c.memory_snapshot() {
                for (acc, &v) in total_updates.iter_mut().zip(mem.memory()) {
                    *acc += v as f64 / n as f64;
                }
            }
            for i in 0..dim {
                assert!(
                    (total_grads[i] - total_updates[i]).abs() < 1e-3,
                    "coord {i}: grads {} vs updates+memory {}",
                    total_grads[i],
                    total_updates[i]
                );
            }
        });
    }

    #[test]
    fn shared_vs_gather_byte_scaling() {
        // CLT-k per-worker download constant in n; local top-k grows.
        let dim = 2000;
        let k = 20;
        let mut scalecom_down = Vec::new();
        let mut localtopk_down = Vec::new();
        for n in [2usize, 8] {
            let mut rng = Rng::new(3);
            let grads = rand_grads(&mut rng, n, dim);
            let mut c1 = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                1.0,
                k,
                fabric(n),
                0,
            );
            scalecom_down.push(c1.step(0, &grads).comm.bytes_down_per_worker);
            let mut c2 = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(LocalTopK::new())),
                1.0,
                k,
                fabric(n),
                0,
            );
            localtopk_down.push(c2.step(0, &grads).comm.bytes_down_per_worker);
        }
        assert_eq!(scalecom_down[0], scalecom_down[1]);
        assert!(localtopk_down[1] > localtopk_down[0] * 2);
    }

    #[test]
    fn true_topk_contracts_at_least_as_well_as_clt_k() {
        // γ̂(true top-k) ≤ γ̂(CLT-k) on the averaged EF gradient.
        let n = 4;
        let dim = 256;
        let k = 16;
        let mut rng = Rng::new(11);
        let grads = rand_grads(&mut rng, n, dim);
        let mk = |m: Mode| Coordinator::new(n, dim, m, 1.0, k, fabric(n), 0);
        let mut c_true = mk(Mode::Compressed(Box::new(TrueTopK)));
        let mut c_clt = mk(Mode::Compressed(Box::new(CltK::exact())));

        let avg_ef = |c: &Coordinator, grads: &[Vec<f32>]| -> Vec<f32> {
            let efs = c.ef_grads(grads);
            let mut avg = vec![0.0f32; dim];
            for e in &efs {
                for (a, &v) in avg.iter_mut().zip(e) {
                    *a += v / n as f32;
                }
            }
            avg
        };
        let y = avg_ef(&c_true, &grads);
        let sel_true = match c_true.step(0, &grads).selection.unwrap() {
            Selection::Shared(ix) => ix,
            _ => panic!(),
        };
        let sel_clt = match c_clt.step(0, &grads).selection.unwrap() {
            Selection::Shared(ix) => ix,
            _ => panic!(),
        };
        let g_true = crate::stats::contraction_coefficient(&y, &sel_true);
        let g_clt = crate::stats::contraction_coefficient(&y, &sel_clt);
        assert!(g_true <= g_clt + 1e-9, "{g_true} vs {g_clt}");
    }

    #[test]
    fn layered_selection_respects_budgets_and_dense_layers() {
        let partition = LayerPartition::from_layers(vec![
            LayerSlice {
                name: "first".into(),
                offset: 0,
                len: 8,
                flops_per_sample: 0.0,
                compress: false, // dense
            },
            LayerSlice {
                name: "rest".into(),
                offset: 8,
                len: 32,
                flops_per_sample: 0.0,
                compress: true,
            },
        ]);
        let ks = vec![8, 4];
        let n = 2;
        let mut c = Coordinator::new(
            n,
            40,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            4,
            fabric(n),
            0,
        )
        .with_layered(partition, ks);
        let mut rng = Rng::new(2);
        let r = c.step(0, &rand_grads(&mut rng, n, 40));
        match r.selection.unwrap() {
            Selection::Shared(idx) => {
                // dense first layer: indices 0..8 all present
                for i in 0..8u32 {
                    assert!(idx.contains(&i));
                }
                assert_eq!(idx.len(), 12); // 8 dense + 4 compressed
            }
            _ => panic!("CLT-k layered must stay shared"),
        }
    }

    #[test]
    fn update_matches_manual_average_on_shared_indices() {
        check("update == masked average of EF grads", 40, |g| {
            let n = g.usize_in(2..=5);
            let dim = g.usize_in(4..=128);
            let k = g.usize_in(1..=dim);
            let grads: Vec<Vec<f32>> = (0..n).map(|_| g.f32_vec_len(dim, 1.0)).collect();
            let mut c = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                1.0,
                k,
                fabric(n),
                0,
            );
            // memory is zero at t=0 → EF grads == grads
            let r = c.step(0, &grads);
            let idx = match r.selection.unwrap() {
                Selection::Shared(ix) => ix,
                _ => panic!(),
            };
            let mut expect = vec![0.0f32; dim];
            for &i in &idx {
                let i = i as usize;
                expect[i] = grads.iter().map(|w| w[i]).sum::<f32>() / n as f32;
            }
            if let Err(i) = allclose(&r.update, &expect, 1e-4, 1e-5) {
                panic!("coord {i}: {} vs {}", r.update[i], expect[i]);
            }
        });
    }

    #[test]
    fn pipelined_synchronous_step_matches_sequential() {
        let n = 4;
        let dim = 64;
        let mk = |backend| {
            Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                0.5,
                8,
                fabric(n),
                2, // cover the dense-warmup → compressed transition
            )
            .with_backend(backend)
        };
        let mut seq = mk(Backend::Sequential);
        let mut pipe = mk(Backend::Pipelined);
        let mut rng = Rng::new(17);
        for t in 0..8 {
            let grads = rand_grads(&mut rng, n, dim);
            let a = seq.step(t, &grads);
            let b = pipe.step(t, &grads);
            assert_eq!(a.selection, b.selection, "t={t}");
            assert_eq!(a.dense, b.dense, "t={t}");
            assert_eq!(a.comm, b.comm, "t={t}");
            assert!(allclose(&a.update, &b.update, 1e-5, 1e-6).is_ok(), "t={t}");
        }
        for (a, b) in seq.memory_snapshot().iter().zip(&pipe.memory_snapshot()) {
            assert!(allclose(a.memory(), b.memory(), 1e-6, 1e-7).is_ok());
        }
    }

    #[test]
    fn overlapped_stream_lags_by_one_and_drains() {
        // On every backend: step_overlapped(t) returns step t−1's result,
        // and finish_overlapped returns the final step.
        for backend in Backend::ALL {
            let n = 3;
            let dim = 32;
            let mut eager = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                1.0,
                4,
                fabric(n),
                0,
            );
            let mut lagged = Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                1.0,
                4,
                fabric(n),
                0,
            )
            .with_backend(backend);
            let mut rng = Rng::new(23);
            let steps = 6;
            let mut streamed = Vec::new();
            for t in 0..steps {
                let grads = rand_grads(&mut rng, n, dim);
                let _ = eager.step(t, &grads);
                if t == 0 {
                    assert!(lagged.step_overlapped(t, &grads).is_none());
                } else {
                    streamed.push(
                        lagged
                            .step_overlapped(t, &grads)
                            .expect("one-step lag after t=0"),
                    );
                }
                assert!(lagged.in_flight());
            }
            streamed.extend(lagged.finish_overlapped());
            assert!(!lagged.in_flight());
            assert_eq!(streamed.len(), steps, "backend {}", backend.label());
            for (t, r) in streamed.iter().enumerate() {
                assert_eq!(r.leader, t % n, "backend {}", backend.label());
            }
            // identical comm ledger to the eager reference
            assert_eq!(eager.fabric.stats().ops, lagged.fabric.stats().ops);
        }
    }

    fn two_layer_partition(dim: usize) -> (LayerPartition, Vec<usize>) {
        assert!(dim % 4 == 0);
        let first = dim / 4;
        let partition = LayerPartition::from_layers(vec![
            LayerSlice {
                name: "first".into(),
                offset: 0,
                len: first,
                flops_per_sample: 0.0,
                compress: true,
            },
            LayerSlice {
                name: "rest".into(),
                offset: first,
                len: dim - first,
                flops_per_sample: 0.0,
                compress: true,
            },
        ]);
        let ks = vec![(first / 4).max(1), ((dim - first) / 8).max(1)];
        (partition, ks)
    }

    #[test]
    fn bucketed_step_matches_monolithic_layered_step() {
        // Same layered config, same gradient stream: the bucketed step's
        // selections are exactly the monolithic ones, shared-path updates
        // agree within the ring tolerance, and the memories stay in
        // lockstep over many steps.
        let n = 3;
        let dim = 64;
        let (partition, ks) = two_layer_partition(dim);
        let plan = crate::comm::BucketPlan::from_partition(&partition, partition.layers[0].len * 4);
        assert_eq!(plan.num_buckets(), 2);
        let mk = || {
            Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                0.5,
                4,
                fabric(n),
                2, // cover the dense-warmup fallback
            )
            .with_layered(partition.clone(), ks.clone())
        };
        let mut mono = mk();
        let mut buck = mk().with_buckets(plan);
        let mut rng = Rng::new(41);
        for t in 0..10 {
            let grads = rand_grads(&mut rng, n, dim);
            let a = mono.step(t, &grads);
            let b = buck.step_bucketed(t, &grads);
            assert_eq!(a.selection, b.selection, "t={t}: bucketing must not change selection");
            assert_eq!(a.leader, b.leader, "t={t}");
            assert_eq!(a.dense, b.dense, "t={t}");
            assert_eq!(a.rate, b.rate, "t={t}");
            assert!(allclose(&a.update, &b.update, 1e-5, 1e-6).is_ok(), "t={t}");
            // total transported bytes agree (per-bucket bookings sum to
            // the monolithic volume on the shared path: same k overall)
            if !a.dense {
                assert_eq!(
                    a.comm.bytes_up_per_worker
                        + a.comm.bytes_down_per_worker,
                    b.comm.bytes_up_per_worker + b.comm.bytes_down_per_worker,
                    "t={t}: bucketing must not change transported volume"
                );
            }
        }
        for (a, b) in mono.memory_snapshot().iter().zip(&buck.memory_snapshot()) {
            assert!(allclose(a.memory(), b.memory(), 1e-6, 1e-7).is_ok());
        }
    }

    #[test]
    fn single_bucket_plan_is_bit_identical_to_monolithic() {
        let n = 2;
        let dim = 32;
        let (partition, ks) = two_layer_partition(dim);
        let mk = || {
            Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                1.0,
                4,
                fabric(n),
                0,
            )
            .with_layered(partition.clone(), ks.clone())
        };
        let mut mono = mk();
        let mut single = mk().with_buckets(crate::comm::BucketPlan::from_partition(&partition, 0));
        let mut rng = Rng::new(9);
        for t in 0..6 {
            let grads = rand_grads(&mut rng, n, dim);
            let a = mono.step(t, &grads);
            let b = single.step_bucketed(t, &grads);
            // the single-bucket plan takes the monolithic path: equality
            // is exact, not tolerance
            assert_eq!(a.update, b.update, "t={t}");
            assert_eq!(a.selection, b.selection, "t={t}");
            assert_eq!(a.comm, b.comm, "t={t}");
        }
        assert_eq!(
            mono.fabric.stats().ops,
            single.fabric.stats().ops,
            "single-bucket ledger must be the monolithic ledger"
        );
    }

    #[test]
    fn bucketed_gather_path_is_bit_identical_to_monolithic() {
        // The gather path reduces per coordinate in worker order on both
        // drivers — equality, not tolerance.
        let n = 4;
        let dim = 64;
        let (partition, ks) = two_layer_partition(dim);
        let plan = crate::comm::BucketPlan::from_partition(&partition, partition.layers[0].len * 4);
        let mk = || {
            Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(LocalTopK::new())),
                1.0,
                4,
                fabric(n),
                0,
            )
            .with_layered(partition.clone(), ks.clone())
        };
        let mut mono = mk();
        let mut buck = mk().with_buckets(plan);
        let mut rng = Rng::new(77);
        for t in 0..8 {
            let grads = rand_grads(&mut rng, n, dim);
            let a = mono.step(t, &grads);
            let b = buck.step_bucketed(t, &grads);
            assert_eq!(a.selection, b.selection, "t={t}");
            assert_eq!(a.update, b.update, "t={t}: gather must be bit-identical");
        }
        for (a, b) in mono.memory_snapshot().iter().zip(&buck.memory_snapshot()) {
            assert_eq!(a.memory(), b.memory());
        }
    }

    #[test]
    fn mixed_kind_buckets_split_the_exchange_by_bucket() {
        // A dense-exempt layer alone in its bucket under a non-commutative
        // scheme: the monolithic step drags its coordinates into the one
        // big gather, while the bucketed step rides the commutative ring
        // reduce for that bucket — selections and values still match; the
        // ledger records one shared reduce + one gather per step.
        let n = 3;
        let dim = 32;
        let partition = LayerPartition::from_layers(vec![
            LayerSlice {
                name: "exempt".into(),
                offset: 0,
                len: 8,
                flops_per_sample: 0.0,
                compress: false, // dense → Shared selection
            },
            LayerSlice {
                name: "compressed".into(),
                offset: 8,
                len: 24,
                flops_per_sample: 0.0,
                compress: true, // local-topk → PerWorker selection
            },
        ]);
        let ks = vec![8usize, 4];
        let plan = crate::comm::BucketPlan::from_partition(&partition, 8 * 4);
        assert_eq!(plan.num_buckets(), 2);
        let mk = || {
            Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(LocalTopK::new())),
                1.0,
                4,
                fabric(n),
                0,
            )
            .with_layered(partition.clone(), ks.clone())
        };
        let mut mono = mk();
        let mut buck = mk().with_buckets(plan);
        let mut rng = Rng::new(19);
        for t in 0..6 {
            let grads = rand_grads(&mut rng, n, dim);
            let a = mono.step(t, &grads);
            let b = buck.step_bucketed(t, &grads);
            // merged selection identical (dense indices replicated to
            // every worker either way)
            assert_eq!(a.selection, b.selection, "t={t}");
            assert_eq!(a.rate, b.rate, "t={t}");
            // values agree within the ring tolerance on the shared
            // bucket, bit-exactly on the gathered one
            assert!(allclose(&a.update, &b.update, 1e-5, 1e-6).is_ok(), "t={t}");
            assert_eq!(a.update[8..], b.update[8..], "gathered bucket bit-exact t={t}");
        }
        // ledger shape: monolithic = one gather per step; bucketed = one
        // shared reduce (the dense bucket) + one gather per step
        assert!(mono
            .fabric
            .stats()
            .ops
            .iter()
            .all(|c| c.op == "sparse_gather"));
        let buck_ops: Vec<&str> = buck.fabric.stats().ops.iter().map(|c| c.op).collect();
        assert_eq!(buck_ops.iter().filter(|&&o| o == "sparse_gather").count(), 6);
        assert_eq!(
            buck_ops
                .iter()
                .filter(|&&o| o == "sparse_allreduce_shared")
                .count(),
            6
        );
    }

    #[test]
    fn overlapped_mode_with_multi_bucket_plan_is_a_clean_error() {
        // The modes used to be silently exclusive: step_overlapped would
        // happily run monolithically with a multi-bucket plan installed.
        // Now it refuses with a pointer to the flag.
        let dim = 32;
        let (partition, ks) = two_layer_partition(dim);
        let plan = crate::comm::BucketPlan::from_partition(&partition, partition.layers[0].len * 4);
        assert!(plan.num_buckets() > 1);
        let mut c = Coordinator::new(
            2,
            dim,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            4,
            fabric(2),
            0,
        )
        .with_layered(partition.clone(), ks)
        .with_buckets(plan);
        let mut rng = Rng::new(4);
        let err = c
            .try_step_overlapped(0, &rand_grads(&mut rng, 2, dim))
            .unwrap_err();
        assert!(err.to_string().contains("--bucket-bytes"), "{err}");
        assert!(!c.in_flight(), "refusal must not leave anything in flight");
        // a single-bucket plan stays compatible (it IS the monolithic path)
        c.set_bucket_plan(Some(crate::comm::BucketPlan::from_partition(&partition, 0)));
        assert!(c.try_step_overlapped(0, &rand_grads(&mut rng, 2, dim)).is_ok());
        let _ = c.finish_overlapped();
    }

    #[test]
    fn bucketed_step_without_layered_config_is_a_clean_error() {
        let dim = 32;
        let (partition, _) = two_layer_partition(dim);
        let plan = crate::comm::BucketPlan::from_partition(&partition, partition.layers[0].len * 4);
        let mut c = Coordinator::new(
            2,
            dim,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            4,
            fabric(2),
            0,
        )
        .with_buckets(plan);
        let mut rng = Rng::new(1);
        let err = c.try_step_bucketed(0, &rand_grads(&mut rng, 2, dim)).unwrap_err();
        assert!(err.to_string().contains("per-layer budgets"), "{err}");
    }

    #[test]
    #[should_panic(expected = "misaligned")]
    fn misaligned_bucket_plan_rejected_at_setup() {
        let dim = 32;
        let (partition, ks) = two_layer_partition(dim);
        // a plan built from a DIFFERENT partition (single layer) cannot
        // align with the two-layer config
        let other = LayerPartition::from_layers(vec![
            LayerSlice {
                name: "a".into(),
                offset: 0,
                len: 20,
                flops_per_sample: 0.0,
                compress: true,
            },
            LayerSlice {
                name: "b".into(),
                offset: 20,
                len: 12,
                flops_per_sample: 0.0,
                compress: true,
            },
        ]);
        let plan = crate::comm::BucketPlan::from_partition(&other, 80);
        let _ = Coordinator::new(
            2,
            dim,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            4,
            fabric(2),
            0,
        )
        .with_layered(partition, ks)
        .with_buckets(plan);
    }

    #[test]
    #[should_panic(expected = "misaligned")]
    fn misaligned_plan_rejected_regardless_of_configuration_order() {
        // with_buckets BEFORE with_layered must hit the same
        // fail-at-setup check — order must not weaken it.
        let dim = 32;
        let (partition, ks) = two_layer_partition(dim);
        let other = LayerPartition::from_layers(vec![
            LayerSlice {
                name: "a".into(),
                offset: 0,
                len: 20,
                flops_per_sample: 0.0,
                compress: true,
            },
            LayerSlice {
                name: "b".into(),
                offset: 20,
                len: 12,
                flops_per_sample: 0.0,
                compress: true,
            },
        ]);
        let plan = crate::comm::BucketPlan::from_partition(&other, 80);
        let _ = Coordinator::new(
            2,
            dim,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            4,
            fabric(2),
            0,
        )
        .with_buckets(plan)
        .with_layered(partition, ks);
    }

    #[test]
    fn set_backend_migrates_memories_between_pool_and_local() {
        let n = 2;
        let dim = 16;
        let mut c = Coordinator::new(
            n,
            dim,
            Mode::Compressed(Box::new(CltK::exact())),
            1.0,
            4,
            fabric(n),
            0,
        );
        let mut rng = Rng::new(3);
        let _ = c.step(0, &rand_grads(&mut rng, n, dim));
        let before = c.memory_snapshot();
        assert!(before.iter().any(|m| m.norm() > 0.0));
        // local → pool → local round-trips the exact memory state
        c.set_backend(Backend::Pipelined);
        for (a, b) in before.iter().zip(&c.memory_snapshot()) {
            assert_eq!(a.memory(), b.memory());
        }
        let _ = c.step(1, &rand_grads(&mut rng, n, dim));
        c.set_backend(Backend::Sequential);
        assert_eq!(c.backend(), Backend::Sequential);
        assert_eq!(c.memories().len(), n);
    }

    #[test]
    fn restore_memories_rolls_back_and_replay_matches() {
        // The reconnect-with-resume contract at coordinator scope: run 4
        // steps, snapshot after step 1, roll back, replay steps 2-3 — the
        // replayed selections and updates must be bit-identical.
        let n = 3;
        let dim = 32;
        let mk = || {
            Coordinator::new(
                n,
                dim,
                Mode::Compressed(Box::new(CltK::exact())),
                0.5,
                4,
                fabric(n),
                0,
            )
        };
        let grads: Vec<Vec<Vec<f32>>> = {
            let mut rng = Rng::new(11);
            (0..4).map(|_| rand_grads(&mut rng, n, dim)).collect()
        };
        let mut c = mk();
        assert_eq!(c.health(), Health::Healthy);
        let mut first: Vec<(Option<Selection>, Vec<f32>)> = Vec::new();
        let mut snap = None;
        for t in 0..4 {
            let r = c.step(t, &grads[t]);
            first.push((r.selection, r.update));
            if t == 1 {
                snap = Some(c.memory_snapshot());
            }
        }
        c.mark_degraded();
        assert_eq!(c.health(), Health::Degraded);
        c.restore_memories(snap.unwrap()).unwrap();
        assert_eq!(c.health(), Health::Healthy);
        for t in 2..4 {
            let r = c.step(t, &grads[t]);
            assert_eq!(r.selection, first[t].0, "replayed selection t={t}");
            assert_eq!(r.update, first[t].1, "replayed update t={t}");
        }
    }

    #[test]
    fn restore_memories_rejects_wrong_shapes_and_pooled_backends() {
        let mut c = Coordinator::new(2, 8, Mode::Dense, 1.0, 8, fabric(2), 0);
        let err = c.restore_memories(vec![EfMemory::new(8, 1.0)]).unwrap_err();
        assert!(err.to_string().contains("workers"), "{err}");
        let err = c
            .restore_memories(vec![EfMemory::new(4, 1.0), EfMemory::new(4, 1.0)])
            .unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        c.set_backend(Backend::Pipelined);
        let snap = c.memory_snapshot();
        let err = c.restore_memories(snap).unwrap_err();
        assert!(err.to_string().contains("pooled"), "{err}");
        // rebuilding via a backend switch stays the supported path
        c.set_backend(Backend::Sequential);
        assert_eq!(c.health(), Health::Healthy);
    }
}
