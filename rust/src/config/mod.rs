//! Configuration: a TOML-subset parser plus the typed configs the
//! launcher consumes.
//!
//! Supported TOML subset (all the launcher needs): `[section]` and
//! `[a.b]` headers, `key = value` with string / integer / float / bool /
//! homogeneous scalar arrays, `#` comments. Files parse into a flat
//! `"section.key" → Value` map with typed accessors; `TrainConfig`
//! converts that (or CLI flags) into the trainer's settings.

pub mod toml;
pub mod train;

pub use toml::{TomlDoc, Value};
pub use train::{OptimizerKind, ScheduleKind, TrainConfig};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_train_config_from_toml() {
        let doc = TomlDoc::parse(
            r#"
            # quickstart config
            [train]
            model = "mlp"
            workers = 4
            steps = 100
            batch_per_worker = 32
            lr = 0.1
            seed = 7

            [compress]
            scheme = "scalecom"
            rate = 92
            beta = 0.1
            warmup_steps = 10

            [fabric]
            topology = "ring"
            bandwidth_gbps = 32.0
            "#,
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.model, "mlp");
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.compress.scheme, "scalecom");
        assert_eq!(cfg.compress.rate, 92);
        assert!((cfg.compress.beta - 0.1).abs() < 1e-6);
        assert_eq!(cfg.fabric_topology, "ring");
    }
}
