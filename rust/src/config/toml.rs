//! TOML-subset parser (see module docs in `config`).

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: flat `"section.key"` map.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    map: BTreeMap<String, Value>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> anyhow::Result<TomlDoc> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unclosed section", lineno + 1))?
                    .trim();
                if name.is_empty()
                    || !name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.')
                {
                    anyhow::bail!("line {}: bad section name '{name}'", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                anyhow::bail!("line {}: empty key", lineno + 1);
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if map.insert(full.clone(), val).is_some() {
                anyhow::bail!("line {}: duplicate key '{full}'", lineno + 1);
            }
        }
        Ok(TomlDoc { map })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<TomlDoc> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&src)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<Value> {
    if s.is_empty() {
        anyhow::bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        // minimal escapes
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => anyhow::bail!("bad escape \\{other:?}"),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Arr(Vec::new()));
        }
        // split on commas — strings with commas unsupported in the subset
        let items: Result<Vec<Value>, _> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    // numbers: int if no '.', 'e', 'E'
    if s.contains('.') || s.contains('e') || s.contains('E') {
        return s
            .parse::<f64>()
            .map(Value::Float)
            .map_err(|_| anyhow::anyhow!("bad float '{s}'"));
    }
    s.parse::<i64>()
        .map(Value::Int)
        .map_err(|_| anyhow::anyhow!("bad value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(
            r#"
            top = 1
            [a]
            s = "hi"     # comment
            i = -42
            f = 2.5
            b = true
            arr = [1, 2, 3]
            [a.b]
            x = 1e3
            "#,
        )
        .unwrap();
        assert_eq!(d.get("top").unwrap().as_i64(), Some(1));
        assert_eq!(d.get("a.s").unwrap().as_str(), Some("hi"));
        assert_eq!(d.get("a.i").unwrap().as_i64(), Some(-42));
        assert_eq!(d.get("a.f").unwrap().as_f64(), Some(2.5));
        // 'b = true' in [a] and the [a.b] section coexist: "a.b" is the
        // bool key, "a.b.x" the section entry.
        assert_eq!(d.get("a.b").unwrap().as_bool(), Some(true));
        assert_eq!(d.get("a.b.x").unwrap().as_f64(), Some(1000.0));
        let arr = d.get("a.arr").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
    }

    #[test]
    fn string_with_hash_and_escapes() {
        let d = TomlDoc::parse(r#"k = "a # not comment\n""#).unwrap();
        assert_eq!(d.get("k").unwrap().as_str(), Some("a # not comment\n"));
    }

    #[test]
    fn defaults_accessors() {
        let d = TomlDoc::parse("x = 5").unwrap();
        assert_eq!(d.usize_or("x", 0), 5);
        assert_eq!(d.usize_or("missing", 9), 9);
        assert_eq!(d.str_or("missing", "d"), "d");
        assert_eq!(d.f64_or("x", 0.0), 5.0);
        assert!(d.bool_or("missing", true));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2").is_err());
        assert!(TomlDoc::parse("[]").is_err());
        assert!(TomlDoc::parse("[bad name]").is_err());
    }

    #[test]
    fn negative_usize_rejected_by_accessor() {
        let d = TomlDoc::parse("x = -1").unwrap();
        assert_eq!(d.get("x").unwrap().as_usize(), None);
    }
}
