//! Typed training configuration (launcher-facing).

use crate::config::toml::TomlDoc;

/// Optimizer selection; Appendix E uses SGD+momentum for ResNets,
/// RMSProp for MobileNetV2, Adam for the Transformer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    SgdMomentum,
    Adam,
    RmsProp,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "sgd" => OptimizerKind::Sgd,
            "sgdm" | "sgd-momentum" => OptimizerKind::SgdMomentum,
            "adam" => OptimizerKind::Adam,
            "rmsprop" => OptimizerKind::RmsProp,
            other => anyhow::bail!("unknown optimizer '{other}'"),
        })
    }
}

/// Learning-rate schedule. Large-batch runs linearly warm the LR up and
/// then decay (Goyal et al. [7], Appendix E).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScheduleKind {
    Constant,
    /// Multiply by `gamma` at each step listed (fractions of total steps).
    StepDecay { gamma: f64 },
    /// Linear warmup to peak over `warmup` steps, then constant.
    LinearWarmup { warmup: usize },
    /// Linear warmup then inverse-sqrt decay (Transformer style).
    WarmupInvSqrt { warmup: usize },
}

/// Compression sub-config.
#[derive(Debug, Clone)]
pub struct CompressConfig {
    /// scheme name for `make_compressor` (or "none").
    pub scheme: String,
    /// target compression rate (chunk size for chunked selection).
    pub rate: usize,
    /// low-pass filter discount factor β (1.0 = classic error feedback).
    pub beta: f32,
    /// steps of dense (uncompressed) warmup — paper uses 1–5 epochs.
    pub warmup_steps: usize,
    /// use the per-layer FLOPs/gradient rate rule instead of a flat rate.
    pub use_flops_rule: bool,
}

impl Default for CompressConfig {
    fn default() -> Self {
        CompressConfig {
            scheme: "scalecom".into(),
            rate: 100,
            beta: 1.0,
            warmup_steps: 0,
            use_flops_rule: false,
        }
    }
}

/// Full training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub workers: usize,
    pub steps: usize,
    pub batch_per_worker: usize,
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub optimizer: OptimizerKind,
    pub schedule: ScheduleKind,
    pub seed: u64,
    pub compress: CompressConfig,
    pub fabric_topology: String,
    pub fabric_bandwidth_gbps: f64,
    /// Execution backend for the coordination step: "sequential" |
    /// "threaded" | "pipelined" | "socket" (`comm::parallel::Backend`).
    /// `pipelined` runs the persistent double-buffering worker pool;
    /// `socket` is that pool over a loopback TCP mesh (multi-process
    /// rings launch via `scalecom node`, which needs `--peers`).
    pub backend: String,
    /// Bucketed gradient exchange: cap (bytes) for the layer-aligned
    /// buckets `Coordinator::step_bucketed` schedules per step, so each
    /// bucket's collective overlaps the next bucket's selection compute.
    /// 0 = monolithic exchange (the pre-bucketing behavior). Implies
    /// per-layer budgets (buckets are layer-aligned).
    pub bucket_bytes: usize,
    /// Wire entropy-codec mode of the socket backend's mesh:
    /// "off" (v1 framing) | "delta" (delta+varint sparse indices) |
    /// "full" (delta + adaptive byte compression). Inert on the
    /// in-process backends, which ship no bytes.
    pub wire_compression: String,
    /// Per-scheme byte-compression algorithm override for dense-chunk
    /// frames: "auto" | "raw" | "lz1" | "lz2".
    pub wire_compression_dense: String,
    /// Like `wire_compression_dense` for sparse/index frames.
    pub wire_compression_sparse: String,
    /// Heartbeat interval (ms) of the socket mesh's liveness machinery:
    /// a dead or wedged peer is detected within 2× this interval. 0 =
    /// no heartbeats (faults surface only at blocking reads). Must match
    /// across nodes (the handshake rejects a heartbeat-less peer on a
    /// heartbeat mesh). Inert on the in-process backends.
    pub heartbeat_ms: u64,
    /// Reconnect-with-resume after a link fault on the multi-process
    /// socket runtime (`scalecom node`): re-rendezvous on the same
    /// listener, agree on a resume point, roll the EF memory back, and
    /// replay — instead of failing the run. Inert on other backends.
    pub reconnect: bool,
    /// Hierarchical ring-of-rings topology for the dense ring
    /// collective on the pooled backends (pipelined/socket): workers
    /// are partitioned into consecutive groups of `group_size`, each
    /// group runs an intra ring, and the group leaders run a level-1
    /// uplink ring. 0 (or 1) = flat ring. Must divide the worker count
    /// and leave at least two groups (`comm::parallel::
    /// validate_group_size` — the same rule simnet profiles enforce).
    pub group_size: usize,
    /// Evaluate every `eval_every` steps (0 = never).
    pub eval_every: usize,
    /// Directory for artifacts (HLO + manifest).
    pub artifacts_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "mlp".into(),
            workers: 4,
            steps: 100,
            batch_per_worker: 32,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 0.0,
            optimizer: OptimizerKind::SgdMomentum,
            schedule: ScheduleKind::Constant,
            seed: 42,
            compress: CompressConfig::default(),
            fabric_topology: "ps".into(),
            fabric_bandwidth_gbps: 32.0,
            backend: "sequential".into(),
            bucket_bytes: 0,
            wire_compression: "off".into(),
            wire_compression_dense: "auto".into(),
            wire_compression_sparse: "auto".into(),
            heartbeat_ms: 0,
            reconnect: false,
            group_size: 0,
            eval_every: 0,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl TrainConfig {
    pub fn from_toml(doc: &TomlDoc) -> anyhow::Result<TrainConfig> {
        let d = TrainConfig::default();
        let optimizer =
            OptimizerKind::parse(doc.str_or("train.optimizer", "sgd-momentum"))?;
        let schedule = match doc.str_or("train.schedule", "constant") {
            "constant" => ScheduleKind::Constant,
            "step-decay" => ScheduleKind::StepDecay {
                gamma: doc.f64_or("train.decay_gamma", 0.1),
            },
            "linear-warmup" => ScheduleKind::LinearWarmup {
                warmup: doc.usize_or("train.warmup_steps", 0),
            },
            "warmup-invsqrt" => ScheduleKind::WarmupInvSqrt {
                warmup: doc.usize_or("train.warmup_steps", 0),
            },
            other => anyhow::bail!("unknown schedule '{other}'"),
        };
        let cfg = TrainConfig {
            model: doc.str_or("train.model", &d.model).to_string(),
            workers: doc.usize_or("train.workers", d.workers),
            steps: doc.usize_or("train.steps", d.steps),
            batch_per_worker: doc.usize_or("train.batch_per_worker", d.batch_per_worker),
            lr: doc.f64_or("train.lr", d.lr),
            momentum: doc.f64_or("train.momentum", d.momentum),
            weight_decay: doc.f64_or("train.weight_decay", d.weight_decay),
            optimizer,
            schedule,
            seed: doc.usize_or("train.seed", d.seed as usize) as u64,
            compress: CompressConfig {
                scheme: doc.str_or("compress.scheme", "scalecom").to_string(),
                rate: doc.usize_or("compress.rate", 100),
                beta: doc.f64_or("compress.beta", 1.0) as f32,
                warmup_steps: doc.usize_or("compress.warmup_steps", 0),
                use_flops_rule: doc.bool_or("compress.use_flops_rule", false),
            },
            fabric_topology: doc.str_or("fabric.topology", &d.fabric_topology).to_string(),
            fabric_bandwidth_gbps: doc.f64_or("fabric.bandwidth_gbps", 32.0),
            backend: doc.str_or("train.backend", &d.backend).to_string(),
            bucket_bytes: doc.usize_or("train.bucket_bytes", d.bucket_bytes),
            wire_compression: doc
                .str_or("train.wire_compression", &d.wire_compression)
                .to_string(),
            wire_compression_dense: doc
                .str_or("train.wire_compression_dense", &d.wire_compression_dense)
                .to_string(),
            wire_compression_sparse: doc
                .str_or("train.wire_compression_sparse", &d.wire_compression_sparse)
                .to_string(),
            heartbeat_ms: doc.usize_or("train.heartbeat_ms", d.heartbeat_ms as usize) as u64,
            reconnect: doc.bool_or("train.reconnect", d.reconnect),
            group_size: doc.usize_or("train.group_size", d.group_size),
            eval_every: doc.usize_or("train.eval_every", 0),
            artifacts_dir: doc.str_or("train.artifacts_dir", &d.artifacts_dir).to_string(),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 1, "workers must be >= 1");
        anyhow::ensure!(self.steps >= 1, "steps must be >= 1");
        anyhow::ensure!(self.batch_per_worker >= 1, "batch_per_worker must be >= 1");
        anyhow::ensure!(self.lr > 0.0, "lr must be positive");
        anyhow::ensure!(
            self.compress.beta > 0.0 && self.compress.beta <= 1.0,
            "beta must be in (0, 1]"
        );
        anyhow::ensure!(self.compress.rate >= 1, "compression rate must be >= 1");
        anyhow::ensure!(
            !(self.bucket_bytes > 0 && self.compress.scheme == "none"),
            "bucket_bytes only applies to compressed schemes (the bucketed \
             exchange rides on per-layer budgets); the dense baseline's \
             exchange is monolithic — drop --bucket-bytes or pick a scheme"
        );
        crate::comm::Backend::parse(&self.backend)?;
        self.wire_codec()?;
        anyhow::ensure!(
            self.heartbeat_ms <= 60_000,
            "heartbeat_ms {} is past the 60 s cap — liveness detection at that \
             scale is slower than the blocking-read timeout it is meant to beat",
            self.heartbeat_ms
        );
        // Same tiling rule the simnet profiles enforce: a group size that
        // doesn't divide the worker count (or leaves a single group) is a
        // config error, not something to silently downgrade to a flat ring.
        crate::comm::parallel::validate_group_size(self.workers, self.group_size)?;
        Ok(())
    }

    /// The heartbeat interval as the socket mesh consumes it (0 = None =
    /// no liveness machinery).
    pub fn heartbeat(&self) -> Option<std::time::Duration> {
        (self.heartbeat_ms > 0).then(|| std::time::Duration::from_millis(self.heartbeat_ms))
    }

    /// Parse the wire-compression strings into the typed codec config
    /// (validated as part of [`TrainConfig::validate`]).
    pub fn wire_codec(&self) -> anyhow::Result<crate::comm::WireCodecConfig> {
        crate::comm::WireCodecConfig::from_strings(
            &self.wire_compression,
            &self.wire_compression_dense,
            &self.wire_compression_sparse,
        )
    }

    /// Global batch size (paper's "BSZ" column).
    pub fn global_batch(&self) -> usize {
        self.workers * self.batch_per_worker
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
        assert_eq!(TrainConfig::default().global_batch(), 128);
    }

    #[test]
    fn optimizer_parse() {
        assert_eq!(OptimizerKind::parse("adam").unwrap(), OptimizerKind::Adam);
        assert_eq!(
            OptimizerKind::parse("sgd-momentum").unwrap(),
            OptimizerKind::SgdMomentum
        );
        assert!(OptimizerKind::parse("lamb").is_err());
    }

    #[test]
    fn schedule_from_toml() {
        let doc = TomlDoc::parse(
            "[train]\nschedule = \"warmup-invsqrt\"\nwarmup_steps = 40\n",
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.schedule, ScheduleKind::WarmupInvSqrt { warmup: 40 });
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = TrainConfig::default();
        c.workers = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.compress.beta = 0.0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.compress.rate = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn unknown_schedule_rejected() {
        let doc = TomlDoc::parse("[train]\nschedule = \"cosine\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn backend_from_toml_and_validation() {
        let doc = TomlDoc::parse("[train]\nbackend = \"threaded\"\n").unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.backend, "threaded");
        let mut c = TrainConfig::default();
        assert_eq!(c.backend, "sequential");
        c.backend = "gpu".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn bucket_bytes_from_toml_defaults_to_monolithic() {
        assert_eq!(TrainConfig::default().bucket_bytes, 0);
        let doc = TomlDoc::parse("[train]\nbucket_bytes = 262144\n").unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.bucket_bytes, 262144);
    }

    #[test]
    fn bucket_bytes_with_dense_scheme_rejected() {
        // Silently ignoring --bucket-bytes on the dense baseline would
        // let the run banner advertise an overlap that never happened.
        let mut c = TrainConfig::default();
        c.bucket_bytes = 4096;
        c.compress.scheme = "none".into();
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("bucket_bytes"), "{err}");
        c.compress.scheme = "scalecom".into();
        c.validate().unwrap();
    }

    #[test]
    fn wire_compression_from_toml_and_validation() {
        assert_eq!(TrainConfig::default().wire_compression, "off");
        let doc = TomlDoc::parse(
            "[train]\nwire_compression = \"full\"\nwire_compression_dense = \"lz2\"\n",
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.wire_compression, "full");
        let codec = cfg.wire_codec().unwrap();
        assert!(codec.packing() && codec.byte_pass());
        let mut c = TrainConfig::default();
        c.wire_compression = "zstd".into();
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.wire_compression_sparse = "lz9".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn fault_tolerance_knobs_from_toml_and_validation() {
        let d = TrainConfig::default();
        assert_eq!(d.heartbeat_ms, 0);
        assert!(!d.reconnect);
        assert_eq!(d.heartbeat(), None);
        let doc = TomlDoc::parse("[train]\nheartbeat_ms = 250\nreconnect = true\n").unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.heartbeat_ms, 250);
        assert!(cfg.reconnect);
        assert_eq!(cfg.heartbeat(), Some(std::time::Duration::from_millis(250)));
        let mut c = TrainConfig::default();
        c.heartbeat_ms = 120_000;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("heartbeat_ms"), "{err}");
    }

    #[test]
    fn group_size_from_toml_and_validation() {
        assert_eq!(TrainConfig::default().group_size, 0);
        let doc = TomlDoc::parse("[train]\nworkers = 8\ngroup_size = 2\n").unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.group_size, 2);
        // A group size that doesn't tile the worker count is rejected at
        // parse time, with the shared remedy wording.
        let mut c = TrainConfig::default();
        c.workers = 4;
        c.group_size = 3;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("does not divide"), "{err}");
        // A single group has no uplink ring to run.
        c.group_size = 4;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("at least 2 groups"), "{err}");
        // 0 and 1 both mean the flat ring and always validate.
        c.group_size = 1;
        c.validate().unwrap();
    }

    #[test]
    fn every_backend_label_validates() {
        // config strings route through `Backend::parse` — each label of
        // `Backend::ALL` must be accepted, including "pipelined"
        for b in crate::comm::Backend::ALL {
            let mut c = TrainConfig::default();
            c.backend = b.label().to_string();
            c.validate().unwrap();
        }
        let doc = TomlDoc::parse("[train]\nbackend = \"pipelined\"\n").unwrap();
        assert_eq!(TrainConfig::from_toml(&doc).unwrap().backend, "pipelined");
    }
}
