//! Analytic forms from the paper's convergence theory (§3, Appendix C/D).
//!
//! These are used by the experiment drivers to report *where theory says
//! the knobs must sit* next to the measured values — e.g. the admissible
//! β-window of Theorem 1 for the empirically measured contraction γ̂.

/// Lemma 1: contraction of a comp() keeping k indices whose Hamming
/// distance to the true top-k is 2d, given top-k contraction γ₀:
///   γ = d/k + (1 − d/k)·γ₀             (Eqn. 7)
pub fn lemma1_gamma(d_over_k: f64, gamma0: f64) -> f64 {
    assert!((0.0..=1.0).contains(&d_over_k), "d/k in [0,1]");
    assert!((0.0..=1.0).contains(&gamma0), "γ₀ in [0,1]");
    d_over_k + (1.0 - d_over_k) * gamma0
}

/// Theorem 1's admissible discounting-factor window (Eqn. 9):
///   (1+γ−√(1−γ²)) / (2(1+γ))  <  β  <  (1+γ+√(1−γ²)) / (2(1+γ))
/// Returns (lo, hi). Requires 0 ≤ γ < 1.
pub fn theorem1_beta_window(gamma: f64) -> (f64, f64) {
    assert!((0.0..1.0).contains(&gamma), "γ in [0,1), got {gamma}");
    let root = (1.0 - gamma * gamma).sqrt();
    let denom = 2.0 * (1.0 + gamma);
    ((1.0 + gamma - root) / denom, (1.0 + gamma + root) / denom)
}

/// Lemma 2: contraction of CLT-k on the *averaged* EF gradient when the
/// n workers' per-vector contractions are γᵢ and pairwise correlation is
/// at least κ:
///   γ = n·Σγᵢ / (1 + κ·n·(n−1))
/// Valid (γ < 1) iff κ > (n·Σγᵢ − 1)/(n(n−1)).
pub fn lemma2_gamma(gammas: &[f64], kappa: f64) -> f64 {
    let n = gammas.len() as f64;
    assert!(n >= 2.0, "Lemma 2 needs n >= 2");
    let sum: f64 = gammas.iter().sum();
    n * sum / (1.0 + kappa * n * (n - 1.0))
}

/// Minimum pairwise correlation κ for Lemma 2's γ < 1.
pub fn lemma2_kappa_threshold(gammas: &[f64]) -> f64 {
    let n = gammas.len() as f64;
    let sum: f64 = gammas.iter().sum();
    (n * sum - 1.0) / (n * (n - 1.0))
}

/// The λ of Lemma 3 / (A30): (1+ε)(1+γ)β² + (1+γ)(β−1)²; memory stays
/// bounded iff λ < 1 for some ε > 0 (we evaluate at ε→0⁺).
pub fn lemma3_lambda(gamma: f64, beta: f64) -> f64 {
    (1.0 + gamma) * beta * beta + (1.0 + gamma) * (beta - 1.0) * (beta - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    #[test]
    fn lemma1_endpoints() {
        // perfect overlap: γ = γ₀; disjoint: γ = 1
        assert_eq!(lemma1_gamma(0.0, 0.3), 0.3);
        assert_eq!(lemma1_gamma(1.0, 0.3), 1.0);
        // paper's Fig 3 regime: d/k=0.7, small γ₀ → γ ≈ 0.7+
        let g = lemma1_gamma(0.7, 0.1);
        assert!((g - 0.73).abs() < 1e-12);
    }

    #[test]
    fn beta_window_properties() {
        check("Theorem 1 β-window", 100, |g| {
            let gamma = g.f32_in(0.0, 0.999) as f64;
            let (lo, hi) = theorem1_beta_window(gamma);
            // window inside (0, 1), centered at 1/2
            assert!(lo > 0.0 && hi < 1.0, "γ={gamma}: ({lo}, {hi})");
            assert!(lo < hi);
            assert!(((lo + hi) / 2.0 - 0.5).abs() < 1e-12);
            // λ < 1 strictly inside the window, ≥ 1 outside
            let mid = 0.5 * (lo + hi);
            assert!(lemma3_lambda(gamma, mid) < 1.0);
            assert!(lemma3_lambda(gamma, hi + 0.01 * (1.0 - hi)) >= 1.0 - 1e-9);
            assert!(lemma3_lambda(gamma, lo * 0.99) >= 1.0 - 1e-9);
        });
    }

    #[test]
    fn beta_window_shrinks_with_gamma() {
        // worse contraction (γ→1) demands stronger filtering: the window
        // collapses onto 1/2 — β=1 (no filter) is admissible only for
        // small γ. This is the theory behind Table 3's β=0.1.
        let (_, hi_small) = theorem1_beta_window(0.1);
        let (_, hi_big) = theorem1_beta_window(0.95);
        assert!(hi_small > hi_big);
        let (lo, hi) = theorem1_beta_window(0.95);
        assert!(hi - lo < 0.35);
        // β=1 never strictly inside for γ > 0
        let (_, hi) = theorem1_beta_window(0.5);
        assert!(hi < 1.0);
    }

    #[test]
    fn paper_beta_01_admissible_for_small_gamma() {
        // the paper trains with β = 0.1..0.3 (footnote 8). β=0.1 sits in
        // the window for well-contracting compressors (γ ≲ 0.25 — which
        // Fig 3's d/k plus a small γ₀ delivers at high overlap), β=0.3
        // up to γ ≈ 0.7.
        let (lo, hi) = theorem1_beta_window(0.15);
        assert!(lo < 0.1 && 0.1 < hi, "β=0.1 ∉ ({lo}, {hi})");
        let (lo, hi) = theorem1_beta_window(0.6);
        assert!(lo < 0.3 && 0.3 < hi);
        // at γ=0.8 the window tightens to (1/3, 2/3): the theory demands
        // a *mid-range* β when contraction is weak
        let (lo, hi) = theorem1_beta_window(0.8);
        assert!((lo - 1.0 / 3.0).abs() < 1e-9 && (hi - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn lemma2_decreases_with_correlation_and_n() {
        let g4 = lemma2_gamma(&[0.1; 4], 0.5);
        let g4_hi = lemma2_gamma(&[0.1; 4], 0.9);
        assert!(g4_hi < g4, "higher κ → smaller γ");
        // Remark 5: with Σγᵢ ~ o(n) and κ ~ O(1), γ ~ O(1/n)
        let g16 = lemma2_gamma(&[0.1; 16], 0.5);
        assert!(g16 < g4, "γ shrinks with n when residues correlate");
    }

    #[test]
    fn lemma2_threshold_consistent() {
        let gammas = [0.2, 0.3, 0.25, 0.25];
        let kappa_min = lemma2_kappa_threshold(&gammas);
        assert!(lemma2_gamma(&gammas, kappa_min + 1e-9) < 1.0 + 1e-6);
        assert!(lemma2_gamma(&gammas, kappa_min * 2.0) < 1.0);
    }

    #[test]
    #[should_panic(expected = "γ in [0,1)")]
    fn beta_window_rejects_gamma_one() {
        let _ = theorem1_beta_window(1.0);
    }
}
