//! Statistical instrumentation behind Figures 2, 3 and A1.
//!
//! - cosine distance between workers' memories (Fig 2a/c)
//! - normalized Hamming distance between index sets (Fig 3, Lemma 1)
//! - log-scale magnitude histograms + overlap (Fig 2b/d)
//! - Q-Q quantiles, linear-fit R², Spearman rank correlation (Fig A1)
//! - contraction coefficient measurement (Lemma 1 empirics)

use crate::util::floats::{dot, l2_norm};

/// Cosine distance `1 − x·y / (‖x‖‖y‖)` (paper footnote 1).
/// Returns 0 for two zero vectors, 1 if exactly one is zero.
pub fn cosine_distance(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len(), "cosine_distance: length mismatch");
    let nx = l2_norm(x);
    let ny = l2_norm(y);
    if nx == 0.0 && ny == 0.0 {
        return 0.0;
    }
    if nx == 0.0 || ny == 0.0 {
        return 1.0;
    }
    1.0 - dot(x, y) / (nx * ny)
}

/// Mean pairwise cosine distance over all worker pairs.
pub fn mean_pairwise_cosine_distance(vecs: &[Vec<f32>]) -> f64 {
    let n = vecs.len();
    if n < 2 {
        return 0.0;
    }
    let mut sum = 0.0;
    let mut count = 0;
    for i in 0..n {
        for j in (i + 1)..n {
            sum += cosine_distance(&vecs[i], &vecs[j]);
            count += 1;
        }
    }
    sum / count as f64
}

/// Hamming distance between two k-index sets, per Eqn. (6):
/// `H(I1, I2) = 2d` where `d = k − |I1 ∩ I2|`. Sets must be sorted.
pub fn hamming_distance(a: &[u32], b: &[u32]) -> usize {
    debug_assert!(a.windows(2).all(|w| w[0] < w[1]));
    debug_assert!(b.windows(2).all(|w| w[0] < w[1]));
    let overlap = sorted_intersection_size(a, b);
    (a.len() - overlap) + (b.len() - overlap)
}

/// `d/k` from Fig 3: the normalized non-overlap of two k-sets
/// (0 = identical, 1 = disjoint). For unequal sizes uses the max size.
pub fn normalized_hamming(a: &[u32], b: &[u32]) -> f64 {
    let k = a.len().max(b.len());
    if k == 0 {
        return 0.0;
    }
    let d = hamming_distance(a, b) as f64 / 2.0;
    d / k as f64
}

/// |A ∩ B| for sorted unique slices, O(|A|+|B|).
pub fn sorted_intersection_size(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut c) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

/// Empirical contraction coefficient of Lemma 1:
/// `γ̂ = ‖y − comp(y)‖² / ‖y‖²` where comp keeps only `indices`.
pub fn contraction_coefficient(y: &[f32], indices: &[u32]) -> f64 {
    let total: f64 = y.iter().map(|&v| (v as f64) * (v as f64)).sum();
    if total == 0.0 {
        return 0.0;
    }
    let kept: f64 = indices
        .iter()
        .map(|&i| {
            let v = y[i as usize] as f64;
            v * v
        })
        .sum();
    (total - kept) / total
}

/// Lemma 1's bound: γ ≤ d/k + (1 − d/k)·γ0, with γ0 the top-k
/// contraction of `y` itself.
pub fn lemma1_bound(y: &[f32], indices: &[u32]) -> f64 {
    let k = indices.len();
    if k == 0 {
        return 1.0;
    }
    let true_topk = crate::util::select::top_k_indices_by_magnitude(y, k.min(y.len()));
    let gamma0 = contraction_coefficient(y, &true_topk);
    let dk = normalized_hamming(&true_topk, indices);
    dk + (1.0 - dk) * gamma0
}

// ---------------------------------------------------------------------
// Histograms (Fig 2b/d)
// ---------------------------------------------------------------------

/// Log-scale magnitude histogram: buckets of |x| in decades
/// [10^lo, 10^hi) split `bins_per_decade` per decade; zeros go to an
/// underflow bucket.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    pub lo_exp: i32,
    pub hi_exp: i32,
    pub bins_per_decade: usize,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl LogHistogram {
    pub fn new(lo_exp: i32, hi_exp: i32, bins_per_decade: usize) -> Self {
        assert!(hi_exp > lo_exp && bins_per_decade >= 1);
        let nbins = ((hi_exp - lo_exp) as usize) * bins_per_decade;
        LogHistogram {
            lo_exp,
            hi_exp,
            bins_per_decade,
            counts: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x);
        }
    }

    pub fn add(&mut self, x: f32) {
        let m = x.abs() as f64;
        if m <= 0.0 || !m.is_finite() {
            self.underflow += 1;
            return;
        }
        let pos = (m.log10() - self.lo_exp as f64) * self.bins_per_decade as f64;
        if pos < 0.0 {
            self.underflow += 1;
        } else if pos >= self.counts.len() as f64 {
            self.overflow += 1;
        } else {
            self.counts[pos as usize] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Histogram-overlap coefficient in [0,1]: Σ min(p_i, q_i) over
    /// normalized bins. Fig 2(b): "true top-k area overlaps more than
    /// 70% with local top-k" — we compute the analogous number.
    pub fn overlap(&self, other: &LogHistogram) -> f64 {
        assert_eq!(self.counts.len(), other.counts.len());
        let ta = self.total().max(1) as f64;
        let tb = other.total().max(1) as f64;
        let mut s = (self.underflow as f64 / ta).min(other.underflow as f64 / tb)
            + (self.overflow as f64 / ta).min(other.overflow as f64 / tb);
        for (&a, &b) in self.counts.iter().zip(&other.counts) {
            s += (a as f64 / ta).min(b as f64 / tb);
        }
        s
    }
}

// ---------------------------------------------------------------------
// Q-Q analysis (Fig A1)
// ---------------------------------------------------------------------

/// `q` evenly-spaced quantiles of |x| (sorted magnitudes).
pub fn magnitude_quantiles(xs: &[f32], q: usize) -> Vec<f64> {
    assert!(q >= 2);
    let mut m: Vec<f64> = xs.iter().map(|&x| x.abs() as f64).collect();
    m.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if m.is_empty() {
        return vec![0.0; q];
    }
    (0..q)
        .map(|i| {
            let pos = i as f64 / (q - 1) as f64 * (m.len() - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            if lo == hi {
                m[lo]
            } else {
                let frac = pos - lo as f64;
                m[lo] * (1.0 - frac) + m[hi] * frac
            }
        })
        .collect()
}

/// Least-squares fit y = a·x + b, returning (a, b, r²).
pub fn linear_fit_r2(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    assert!(n >= 2.0, "need at least 2 points");
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxx: f64 = x.iter().map(|&v| (v - mx) * (v - mx)).sum();
    let sxy: f64 = x.iter().zip(y).map(|(&u, &v)| (u - mx) * (v - my)).sum();
    let syy: f64 = y.iter().map(|&v| (v - my) * (v - my)).sum();
    if sxx == 0.0 || syy == 0.0 {
        return (0.0, my, if syy == 0.0 { 1.0 } else { 0.0 });
    }
    let a = sxy / sxx;
    let b = my - a * mx;
    let r2 = (sxy * sxy) / (sxx * syy);
    (a, b, r2)
}

/// Spearman rank correlation of |x| vs |y| (Fig A1: ρ = 0.657 between a
/// worker's EF-gradient magnitudes and the all-reduced ones).
pub fn spearman_correlation(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let rx = ranks_of_magnitude(x);
    let ry = ranks_of_magnitude(y);
    let (_, _, r2) = linear_fit_r2(&rx, &ry);
    // sign from the slope of the rank fit
    let (a, _, _) = linear_fit_r2(&rx, &ry);
    r2.sqrt() * a.signum()
}

fn ranks_of_magnitude(xs: &[f32]) -> Vec<f64> {
    let n = xs.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        xs[a]
            .abs()
            .partial_cmp(&xs[b].abs())
            .unwrap()
            .then(a.cmp(&b))
    });
    let mut ranks = vec![0.0; n];
    // average ranks over ties
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[order[j + 1]].abs() == xs[order[i]].abs() {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for &o in &order[i..=j] {
            ranks[o] = avg;
        }
        i = j + 1;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    #[test]
    fn cosine_distance_basics() {
        assert!(cosine_distance(&[1.0, 0.0], &[1.0, 0.0]).abs() < 1e-12);
        assert!((cosine_distance(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((cosine_distance(&[1.0, 0.0], &[-1.0, 0.0]) - 2.0).abs() < 1e-12);
        assert_eq!(cosine_distance(&[0.0], &[0.0]), 0.0);
        assert_eq!(cosine_distance(&[0.0], &[1.0]), 1.0);
    }

    #[test]
    fn cosine_scale_invariant() {
        check("cosine scale-invariant", 60, |g| {
            let n = g.usize_in(1..=64);
            let x = g.f32_vec_len(n, 1.0);
            let y = g.f32_vec_len(n, 1.0);
            let s = g.f32_in(0.1, 10.0);
            let xs: Vec<f32> = x.iter().map(|&v| v * s).collect();
            let d1 = cosine_distance(&x, &y);
            let d2 = cosine_distance(&xs, &y);
            assert!((d1 - d2).abs() < 1e-4, "{d1} vs {d2}");
        });
    }

    #[test]
    fn mean_pairwise_over_three() {
        let v = vec![vec![1.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]];
        // pairs: (0,1)=0, (0,2)=1, (1,2)=1 → mean 2/3
        assert!((mean_pairwise_cosine_distance(&v) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean_pairwise_cosine_distance(&v[..1]), 0.0);
    }

    #[test]
    fn hamming_eqn6() {
        // identical sets → 0; disjoint k-sets → 2k
        assert_eq!(hamming_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(hamming_distance(&[1, 2], &[3, 4]), 4);
        assert_eq!(hamming_distance(&[1, 2, 3], &[2, 3, 4]), 2);
        assert_eq!(normalized_hamming(&[1, 2], &[3, 4]), 1.0);
        assert_eq!(normalized_hamming(&[1, 2], &[1, 2]), 0.0);
        assert_eq!(normalized_hamming(&[], &[]), 0.0);
    }

    #[test]
    fn intersection_size_prop() {
        check("intersection bounds", 60, |g| {
            let n = g.usize_in(0..=64);
            let m = g.usize_in(0..=64);
            let dim = 128;
            let a = g.rng().sample_indices(dim, n.min(dim));
            let b = g.rng().sample_indices(dim, m.min(dim));
            let c = sorted_intersection_size(&a, &b);
            assert!(c <= a.len() && c <= b.len());
            assert_eq!(sorted_intersection_size(&a, &a), a.len());
        });
    }

    #[test]
    fn contraction_zero_when_all_kept() {
        let y = [1.0f32, -2.0, 3.0];
        assert_eq!(contraction_coefficient(&y, &[0, 1, 2]), 0.0);
        assert_eq!(contraction_coefficient(&y, &[]), 1.0);
        assert_eq!(contraction_coefficient(&[0.0, 0.0], &[]), 0.0);
    }

    #[test]
    fn lemma1_bound_holds_in_expectation() {
        // Lemma 1 bounds E‖y − comp(y)‖² over the uniform choice of
        // *which* k−d top-k coordinates stay in the overlap (A10–A12).
        // Verify: draw many index sets with a fixed overlap size (k−d
        // uniform from the true top-k, d arbitrary from outside) and
        // compare the mean contraction against the bound.
        check("Lemma 1 contraction bound (expectation)", 30, |g| {
            let dim = g.usize_in(16..=128);
            let k = g.usize_in(2..=dim / 2);
            let d = g.usize_in(0..=k); // non-overlap size
            let y = g.f32_vec_len(dim, 1.0);
            let topk = crate::util::select::top_k_indices_by_magnitude(&y, k);
            let outside: Vec<u32> = (0..dim as u32).filter(|i| !topk.contains(i)).collect();
            let d = d.min(outside.len());
            let trials = 300;
            let mut mean_gamma = 0.0;
            let mut bound = 0.0;
            for _ in 0..trials {
                // keep k−d uniform from topk
                let mut kept: Vec<u32> = {
                    let mut t = topk.clone();
                    g.rng().shuffle(&mut t);
                    t[..k - d].to_vec()
                };
                // fill with d arbitrary outside coordinates
                let mut o = outside.clone();
                g.rng().shuffle(&mut o);
                kept.extend_from_slice(&o[..d]);
                kept.sort_unstable();
                mean_gamma += contraction_coefficient(&y, &kept) / trials as f64;
                bound = lemma1_bound(&y, &kept); // same for all draws (same d/k)
            }
            assert!(
                mean_gamma <= bound + 0.02,
                "E[γ̂]={mean_gamma} > bound={bound} (dim={dim} k={k} d={d})"
            );
        });
    }

    #[test]
    fn loghist_counts_and_overlap() {
        let mut h1 = LogHistogram::new(-6, 2, 4);
        h1.add_all(&[0.0, 1.0, -1.0, 10.0, 1e-8]);
        assert_eq!(h1.total(), 5);
        assert_eq!(h1.underflow, 2); // 0.0 and 1e-8
        let mut h2 = LogHistogram::new(-6, 2, 4);
        h2.add_all(&[0.0, 1.0, -1.0, 10.0, 1e-8]);
        assert!((h1.overlap(&h2) - 1.0).abs() < 1e-12);
        let mut h3 = LogHistogram::new(-6, 2, 4);
        h3.add_all(&[1e5; 5]); // all overflow
        assert!(h1.overlap(&h3) < 0.01);
    }

    #[test]
    fn quantiles_monotone() {
        check("quantiles monotone", 40, |g| {
            let n = g.usize_in(1..=128);
            let xs = g.f32_vec_len(n, 3.0);
            let q = magnitude_quantiles(&xs, 11);
            assert_eq!(q.len(), 11);
            assert!(q.windows(2).all(|w| w[0] <= w[1] + 1e-12));
        });
    }

    #[test]
    fn linear_fit_exact_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linear_fit_r2(&x, &y);
        assert!((a - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_perfect_and_inverse() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [2.0f32, 4.0, 6.0, 8.0];
        assert!((spearman_correlation(&x, &y) - 1.0).abs() < 1e-9);
        // inverse *magnitude* order
        let z = [8.0f32, 6.0, 4.0, 2.0];
        assert!((spearman_correlation(&x, &z) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_ties_averaged() {
        let x = [1.0f32, 1.0, 2.0];
        let y = [1.0f32, 1.0, 2.0];
        assert!((spearman_correlation(&x, &y) - 1.0).abs() < 1e-9);
    }
}

pub mod theory;
