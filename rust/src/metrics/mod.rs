//! Run metrics: loss curves, communication counters, CSV/JSON output.
//!
//! Every experiment driver emits both a human-readable table on stdout
//! and machine-readable CSV under `results/` so the paper's figures can
//! be re-plotted from the raw series.

use crate::json::{obj, Json};
use std::io::Write;
use std::path::{Path, PathBuf};

/// One training-run record: per-step scalars keyed by column name.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
    pub meta: Vec<(String, String)>,
}

impl RunLog {
    pub fn new(name: &str, columns: &[&str]) -> Self {
        RunLog {
            name: name.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            meta: Vec::new(),
        }
    }

    pub fn add_meta(&mut self, key: &str, value: &str) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(
            row.len(),
            self.columns.len(),
            "row width {} != {} columns",
            row.len(),
            self.columns.len()
        );
        self.rows.push(row);
    }

    pub fn column(&self, name: &str) -> Option<Vec<f64>> {
        let idx = self.columns.iter().position(|c| c == name)?;
        Some(self.rows.iter().map(|r| r[idx]).collect())
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        self.column(name)?.last().copied()
    }

    /// Mean of the last `n` values of a column — smoothed final metric.
    pub fn tail_mean(&self, name: &str, n: usize) -> Option<f64> {
        let col = self.column(name)?;
        if col.is_empty() {
            return None;
        }
        let tail = &col[col.len().saturating_sub(n)..];
        Some(tail.iter().sum::<f64>() / tail.len() as f64)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.meta {
            s.push_str(&format!("# {k} = {v}\n"));
        }
        s.push_str(&self.columns.join(","));
        s.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            s.push_str(&cells.join(","));
            s.push('\n');
        }
        s
    }

    pub fn save_csv(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(path)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::from(self.name.as_str())),
            (
                "meta",
                Json::Obj(
                    self.meta
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::from(v.as_str())))
                        .collect(),
                ),
            ),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| Json::from(c.as_str())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|&v| Json::Num(v)).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Fixed-width console table used by the experiment drivers to print the
/// paper's rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runlog_push_and_columns() {
        let mut l = RunLog::new("test", &["step", "loss"]);
        l.push(vec![0.0, 2.0]);
        l.push(vec![1.0, 1.0]);
        assert_eq!(l.column("loss").unwrap(), vec![2.0, 1.0]);
        assert_eq!(l.last("loss"), Some(1.0));
        assert_eq!(l.tail_mean("loss", 2), Some(1.5));
        assert_eq!(l.column("nope"), None);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn runlog_rejects_bad_row() {
        let mut l = RunLog::new("test", &["a"]);
        l.push(vec![1.0, 2.0]);
    }

    #[test]
    fn csv_format() {
        let mut l = RunLog::new("t", &["a", "b"]);
        l.add_meta("model", "mlp");
        l.push(vec![1.0, 2.5]);
        let csv = l.to_csv();
        assert!(csv.starts_with("# model = mlp\na,b\n1,2.5\n"));
    }

    #[test]
    fn csv_roundtrip_to_disk() {
        let dir = std::env::temp_dir().join("scalecom_test_metrics");
        let mut l = RunLog::new("roundtrip", &["x"]);
        l.push(vec![7.0]);
        let p = l.save_csv(&dir).unwrap();
        let content = std::fs::read_to_string(p).unwrap();
        assert!(content.contains("7"));
    }

    #[test]
    fn json_export_parses() {
        let mut l = RunLog::new("j", &["a"]);
        l.push(vec![1.0]);
        let s = l.to_json().to_string();
        let v = crate::json::Json::parse(&s).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("j"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer-name".into(), "2".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("longer-name"));
    }
}
