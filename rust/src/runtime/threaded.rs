//! Threaded backend: thread-per-worker execution of Algorithm 1.
//!
//! The sequential coordinator iterates workers on one thread. This engine
//! runs every per-worker stage — error-feedback gradient, sparsify,
//! collective exchange, low-pass memory update — on a dedicated OS thread
//! per worker, with the exchange going through the real channel
//! collectives in `comm::parallel` (ring reduce-scatter/all-gather for
//! the commutative shared-index path, star gather for the build-up path).
//!
//! Worker state stays owned by the `Coordinator`, so each step borrows
//! the per-worker pieces into `std::thread::scope` threads instead of
//! moving them into long-lived workers; every closure touches only its
//! own worker's memory, gradient, and mesh endpoints. (The `pipelined`
//! backend in `runtime::pipelined` is the long-lived-worker counterpart:
//! lanes own their memories behind `Coordinator::memory_snapshot`, and
//! steps double-buffer against in-flight collectives.)
//!
//! Semantics vs the sequential backend (locked by
//! `rust/tests/backend_parity.rs`):
//!   - EF gradients, selections, memory updates: bit-identical (the math
//!     is per-worker and order-free);
//!   - gather reduction: bit-identical (the root reduces in worker order,
//!     exactly like `Fabric::sparse_gather_avg`);
//!   - ring reductions: equal up to f32 reduction-order rounding
//!     (rtol 1e-5 / atol 1e-6) — see the determinism contract in
//!     `comm::parallel`.

use crate::comm::parallel::{ring, star};
use crate::comm::GatherStats;
use crate::compress::{sparsify, EfMemory};

/// Error-feedback gradients `m_i + ∇f_i`, one worker thread each.
/// Identical to `Coordinator::ef_grads` output.
pub fn parallel_ef_grads(memories: &[EfMemory], grads: &[Vec<f32>]) -> Vec<Vec<f32>> {
    assert_eq!(memories.len(), grads.len());
    if memories.len() <= 1 {
        return memories.iter().zip(grads).map(|(m, g)| m.ef_grad(g)).collect();
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = memories
            .iter()
            .zip(grads)
            .map(|(m, g)| s.spawn(move || m.ef_grad(g)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("ef-grad worker panicked"))
            .collect()
    })
}

/// Dense all-reduce average over worker threads via the ring.
pub fn dense_allreduce_avg(grads: &[Vec<f32>]) -> Vec<f32> {
    let n = grads.len();
    assert!(n >= 1, "dense_allreduce over no gradients");
    let nodes = ring(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = nodes
            .into_iter()
            .zip(grads)
            .map(|(node, g)| {
                s.spawn(move || {
                    let mut buf = g.clone();
                    node.allreduce_avg(&mut buf);
                    (node.id == 0).then_some(buf)
                })
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| h.join().expect("dense-allreduce worker panicked"))
            .next()
            .expect("ring root result")
    })
}

/// Shared-index exchange (the commutative CLT-k path): every worker
/// sparsifies its EF gradient with the broadcast index set `idx`,
/// ring-all-reduces the k values, and applies its low-pass memory update
/// — all inside its own thread. Returns the averaged values aligned with
/// `idx`.
pub fn exchange_shared(
    memories: &mut [EfMemory],
    grads: &[Vec<f32>],
    efs: &[Vec<f32>],
    idx: &[u32],
) -> Vec<f32> {
    let n = memories.len();
    assert!(n >= 1 && grads.len() == n && efs.len() == n);
    let nodes = ring(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = nodes
            .into_iter()
            .zip(memories.iter_mut())
            .zip(grads.iter().zip(efs))
            .map(|((node, mem), (grad, ef))| {
                s.spawn(move || {
                    let mut vals: Vec<f32> =
                        idx.iter().map(|&i| ef[i as usize]).collect();
                    node.allreduce_avg(&mut vals);
                    // memory update (Eqn. 5) with the transmitted indices
                    mem.update_after_send(grad, idx);
                    (node.id == 0).then_some(vals)
                })
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| h.join().expect("shared-exchange worker panicked"))
            .next()
            .expect("ring root result")
    })
}

/// Per-worker-index exchange (the non-commutative build-up path): each
/// worker sparsifies with its own set and sends it to the root over the
/// star; the root reduces in worker order — the exact order and
/// arithmetic of `Fabric::sparse_gather_avg`, so the result is
/// bit-identical to the sequential backend. Memory updates run on each
/// worker's thread. Returns the dense average plus the wire-shape summary
/// for the analytic cost model.
pub fn exchange_gather(
    memories: &mut [EfMemory],
    grads: &[Vec<f32>],
    efs: &[Vec<f32>],
    per: &[Vec<u32>],
) -> (Vec<f32>, GatherStats) {
    let n = memories.len();
    assert!(n >= 1 && grads.len() == n && efs.len() == n && per.len() == n);
    let dim = efs[0].len();
    let nodes = star(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = nodes
            .into_iter()
            .zip(memories.iter_mut())
            .zip(grads.iter().zip(efs.iter().zip(per)))
            .map(|((node, mem), (grad, (ef, idx)))| {
                s.spawn(move || {
                    let sg = sparsify(ef, idx);
                    let gathered = node.gather(sg);
                    mem.update_after_send(grad, idx);
                    // One shared definition of the gather arithmetic
                    // (worker-order root reduction) for every backend.
                    gathered.map(|all| crate::comm::fabric::reduce_gathered(&all, dim))
                })
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| h.join().expect("gather-exchange worker panicked"))
            .next()
            .expect("star root result")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::floats::allclose;
    use crate::util::rng::Rng;

    fn rand_grads(seed: u64, n: usize, dim: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut v = vec![0.0; dim];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn parallel_ef_grads_matches_sequential() {
        for n in [1usize, 2, 5] {
            let dim = 37;
            let grads = rand_grads(n as u64, n, dim);
            let mut memories: Vec<EfMemory> =
                (0..n).map(|_| EfMemory::new(dim, 0.5)).collect();
            for (m, g) in memories.iter_mut().zip(&grads) {
                m.update_after_send(g, &[0, 3]);
            }
            let seq: Vec<Vec<f32>> = memories
                .iter()
                .zip(&grads)
                .map(|(m, g)| m.ef_grad(g))
                .collect();
            let par = parallel_ef_grads(&memories, &grads);
            // per-worker math, no cross-worker reduction → bit-identical
            assert_eq!(seq, par);
        }
    }

    #[test]
    fn threaded_dense_allreduce_matches_sequential_within_tolerance() {
        for n in [1usize, 2, 3, 8] {
            let dim = 101;
            let grads = rand_grads(7 + n as u64, n, dim);
            let mut expect = vec![0.0f32; dim];
            for g in &grads {
                for (e, &v) in expect.iter_mut().zip(g) {
                    *e += v;
                }
            }
            let inv = 1.0 / n as f32;
            expect.iter_mut().for_each(|v| *v *= inv);
            let got = dense_allreduce_avg(&grads);
            if let Err(i) = allclose(&got, &expect, 1e-5, 1e-6) {
                panic!("n={n} coord {i}: {} vs {}", got[i], expect[i]);
            }
        }
    }

    #[test]
    fn exchange_shared_updates_memories_like_sequential() {
        let n = 4;
        let dim = 64;
        let k = 8;
        let grads = rand_grads(11, n, dim);
        let mut mem_thr: Vec<EfMemory> =
            (0..n).map(|_| EfMemory::new(dim, 0.25)).collect();
        let mut mem_seq = mem_thr.clone();
        let efs: Vec<Vec<f32>> = mem_thr
            .iter()
            .zip(&grads)
            .map(|(m, g)| m.ef_grad(g))
            .collect();
        let idx = crate::util::select::top_k_indices_by_magnitude(&efs[0], k);

        let vals = exchange_shared(&mut mem_thr, &grads, &efs, &idx);

        // reference: sequential sum + per-worker update
        let mut expect = vec![0.0f32; k];
        for ef in &efs {
            for (e, &i) in expect.iter_mut().zip(&idx) {
                *e += ef[i as usize];
            }
        }
        expect.iter_mut().for_each(|v| *v /= n as f32);
        for mem in mem_seq.iter_mut().zip(&grads) {
            mem.0.update_after_send(mem.1, &idx);
        }
        assert!(allclose(&vals, &expect, 1e-5, 1e-6).is_ok());
        for (a, b) in mem_thr.iter().zip(&mem_seq) {
            assert_eq!(a.memory(), b.memory(), "memory updates are per-worker");
        }
    }

    #[test]
    fn exchange_gather_is_bit_identical_to_fabric_reduction() {
        use crate::comm::{Fabric, FabricConfig};
        let n = 5;
        let dim = 48;
        let grads = rand_grads(13, n, dim);
        let mut memories: Vec<EfMemory> =
            (0..n).map(|_| EfMemory::new(dim, 1.0)).collect();
        let efs: Vec<Vec<f32>> = memories
            .iter()
            .zip(&grads)
            .map(|(m, g)| m.ef_grad(g))
            .collect();
        let per: Vec<Vec<u32>> = efs
            .iter()
            .map(|ef| crate::util::select::top_k_indices_by_magnitude(ef, 6))
            .collect();

        let (avg, gs) = exchange_gather(&mut memories, &grads, &efs, &per);

        let sparses: Vec<_> = efs
            .iter()
            .zip(&per)
            .map(|(ef, idx)| sparsify(ef, idx))
            .collect();
        let mut fabric = Fabric::new(FabricConfig {
            workers: n,
            ..FabricConfig::default()
        });
        let expect = fabric.sparse_gather_avg(&sparses);
        // same reduction order, same arithmetic → exactly equal
        assert_eq!(avg, expect);
        assert_eq!(gs, GatherStats::from_sparses(&sparses));
    }
}
