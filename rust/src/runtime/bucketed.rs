//! Bucketed-exchange scheduling: the pure bookkeeping behind
//! `Coordinator::step_bucketed`.
//!
//! The driver walks the buckets of a [`BucketPlan`] in **backward
//! order** — highest offset first, mirroring backprop, which produces
//! the last layers' gradients first — submitting each bucket's
//! collective to the comm lanes as soon as its EF-gradient/CLT-k
//! selection is done, so bucket b's exchange is in flight while bucket
//! b−1's selection computes. This module holds the order and the
//! merge/aggregation helpers; the driving itself lives on the
//! `Coordinator` (it owns the pool, the fabric, and the compressor).
//!
//! The merge is deliberately identical to `select_layered`'s: per-bucket
//! selections, rebased to global coordinates and concatenated in
//! **forward** bucket order, reproduce the monolithic layered selection
//! exactly — that is the bucketed half of the determinism contract.

use crate::comm::bucket::BucketPlan;
use crate::comm::CommCost;
use crate::compress::Selection;

/// Bucket ids in submission order: backward (reverse offset) order, the
/// order backprop would hand the driver finished gradient slices.
pub fn backward_order(plan: &BucketPlan) -> Vec<usize> {
    (0..plan.num_buckets()).rev().collect()
}

/// Merge per-bucket selections (bucket-local indices, one entry per
/// bucket in forward order) into one global [`Selection`], exactly as
/// `select_layered` merges per-layer selections: if every bucket stayed
/// shared the result is shared; one per-worker bucket makes the whole
/// step per-worker, with shared buckets' indices replicated to every
/// worker.
pub fn merge_selections(plan: &BucketPlan, per_bucket: &[Selection], n: usize) -> Selection {
    assert_eq!(
        per_bucket.len(),
        plan.num_buckets(),
        "one selection per bucket"
    );
    let any_per_worker = per_bucket.iter().any(|s| !s.is_shared());
    if !any_per_worker {
        let mut shared: Vec<u32> = Vec::new();
        for (b, sel) in per_bucket.iter().enumerate() {
            let off = plan.bucket(b).offset as u32;
            match sel {
                Selection::Shared(idx) => shared.extend(idx.iter().map(|&i| i + off)),
                Selection::PerWorker(_) => unreachable!("checked shared above"),
            }
        }
        return Selection::Shared(shared);
    }
    let mut per_worker: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (b, sel) in per_bucket.iter().enumerate() {
        let off = plan.bucket(b).offset as u32;
        match sel {
            Selection::Shared(idx) => {
                for pw in per_worker.iter_mut() {
                    pw.extend(idx.iter().map(|&i| i + off));
                }
            }
            Selection::PerWorker(per) => {
                assert_eq!(per.len(), n, "bucket selection sized for a different n");
                for (pw, idx) in per_worker.iter_mut().zip(per) {
                    pw.extend(idx.iter().map(|&i| i + off));
                }
            }
        }
    }
    Selection::PerWorker(per_worker)
}

/// Coordinates one worker transmits under a merged selection — the
/// same `sent` the monolithic step reports (max over workers for the
/// gather path), so the bucketed step's compression-rate accounting is
/// identical to the monolithic step's.
pub fn sent_coords(selection: &Selection) -> usize {
    match selection {
        Selection::Shared(idx) => idx.len(),
        Selection::PerWorker(per) => per.iter().map(|p| p.len()).max().unwrap_or(0),
    }
}

/// Fold the per-bucket collective costs into one step-level record:
/// bytes and bottleneck traffic add, hops add (each bucket's collective
/// serializes its own latency chain), and the modeled times add — the
/// *wall-clock* win of bucketing comes from overlapping this comm total
/// with compute (`perfmodel::step_time_bucketed`), not from shrinking
/// the comm itself.
pub fn aggregate_comm(costs: &[CommCost]) -> CommCost {
    assert!(!costs.is_empty(), "aggregating no collective costs");
    let mut total = CommCost {
        op: "bucketed_exchange",
        ..CommCost::default()
    };
    for c in costs {
        total.bytes_up_per_worker += c.bytes_up_per_worker;
        total.bytes_down_per_worker += c.bytes_down_per_worker;
        total.bottleneck_bytes += c.bottleneck_bytes;
        total.time_s += c.time_s;
        total.hops += c.hops;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::rate::LayerSlice;
    use crate::compress::LayerPartition;

    fn plan(lens: &[usize], cap_bytes: usize) -> BucketPlan {
        let mut layers = Vec::new();
        let mut off = 0;
        for (i, &len) in lens.iter().enumerate() {
            layers.push(LayerSlice {
                name: format!("l{i}"),
                offset: off,
                len,
                flops_per_sample: 0.0,
                compress: true,
            });
            off += len;
        }
        BucketPlan::from_partition(&LayerPartition::from_layers(layers), cap_bytes)
    }

    #[test]
    fn backward_order_is_reverse_offset_order() {
        let p = plan(&[4, 4, 4], 16);
        assert_eq!(p.num_buckets(), 3);
        assert_eq!(backward_order(&p), vec![2, 1, 0]);
    }

    #[test]
    fn merging_shared_buckets_rebases_and_concatenates_forward() {
        let p = plan(&[4, 4], 16);
        let merged = merge_selections(
            &p,
            &[
                Selection::Shared(vec![1, 3]),
                Selection::Shared(vec![0, 2]),
            ],
            2,
        );
        assert_eq!(merged, Selection::Shared(vec![1, 3, 4, 6]));
        assert_eq!(sent_coords(&merged), 4);
    }

    #[test]
    fn one_per_worker_bucket_makes_the_merge_per_worker() {
        let p = plan(&[4, 4], 16);
        let merged = merge_selections(
            &p,
            &[
                Selection::Shared(vec![2]),
                Selection::PerWorker(vec![vec![0], vec![1, 3]]),
            ],
            2,
        );
        match &merged {
            Selection::PerWorker(per) => {
                assert_eq!(per[0], vec![2, 4]);
                assert_eq!(per[1], vec![2, 5, 7]);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(sent_coords(&merged), 3);
    }

    #[test]
    fn aggregate_comm_sums_every_axis() {
        let c = |up: usize, t: f64| CommCost {
            op: "sparse_allreduce_shared",
            bytes_up_per_worker: up,
            bytes_down_per_worker: up,
            bottleneck_bytes: 2 * up,
            time_s: t,
            hops: 3,
        };
        let total = aggregate_comm(&[c(10, 0.5), c(6, 0.25)]);
        assert_eq!(total.op, "bucketed_exchange");
        assert_eq!(total.bytes_up_per_worker, 16);
        assert_eq!(total.bytes_down_per_worker, 16);
        assert_eq!(total.bottleneck_bytes, 32);
        assert_eq!(total.hops, 6);
        assert!((total.time_s - 0.75).abs() < 1e-12);
    }
}
