//! PJRT execution engine: loads HLO-text artifacts, compiles them on the
//! CPU PJRT client, and exposes typed step calls to the trainer.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All artifacts are lowered with
//! `return_tuple=True`, so every output is one tuple literal.

use crate::data::Batch;
use crate::runtime::manifest::{Dtype, Manifest, ModelManifest};
use anyhow::{Context, Result};
use std::path::Path;

/// Owns the PJRT client and compiled executables for one model.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Load and compile all four artifacts of a model.
    pub fn load_model(&self, manifest: &Manifest, name: &str) -> Result<LoadedModel> {
        let mm = manifest.model(name)?.clone();
        Ok(LoadedModel {
            train: self.compile(&mm.train_hlo)?,
            eval: self.compile(&mm.eval_hlo)?,
            compress: self.compile(&mm.compress_hlo)?,
            apply: self.compile(&mm.apply_hlo)?,
            mm,
        })
    }
}

/// Compiled executables + manifest for one model.
pub struct LoadedModel {
    pub mm: ModelManifest,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    compress: xla::PjRtLoadedExecutable,
    apply: xla::PjRtLoadedExecutable,
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

fn run_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
    Ok(result.to_tuple()?)
}

impl LoadedModel {
    /// Build the (x, y) literals from a dataset batch, converting token
    /// features to i32 when the artifact expects integer inputs.
    fn batch_literals(&self, batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
        anyhow::ensure!(
            batch.batch == self.mm.batch,
            "batch size {} != artifact batch {} for model '{}'",
            batch.batch,
            self.mm.batch,
            self.mm.name
        );
        anyhow::ensure!(
            batch.x.len() == self.mm.x.elements(),
            "x has {} elements, artifact expects {}",
            batch.x.len(),
            self.mm.x.elements()
        );
        let x = match self.mm.x.dtype {
            Dtype::F32 => lit_f32(&batch.x, &self.mm.x.dims_i64())?,
            Dtype::I32 => {
                let toks: Vec<i32> = batch.x.iter().map(|&t| t as i32).collect();
                lit_i32(&toks, &self.mm.x.dims_i64())?
            }
        };
        anyhow::ensure!(
            batch.y.len() == self.mm.y.elements(),
            "y has {} elements, artifact expects {}",
            batch.y.len(),
            self.mm.y.elements()
        );
        let y = lit_i32(&batch.y, &self.mm.y.dims_i64())?;
        Ok((x, y))
    }

    /// Forward+backward: `(params, x, y) → (loss, grads)`.
    pub fn train_step(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        anyhow::ensure!(params.len() == self.mm.dim, "params dim mismatch");
        let (x, y) = self.batch_literals(batch)?;
        let p = lit_f32(params, &[self.mm.dim as i64])?;
        let out = run_tuple(&self.train, &[p, x, y])?;
        anyhow::ensure!(out.len() == 2, "train artifact returned {} outputs", out.len());
        let loss = out[0].to_vec::<f32>()?[0];
        let grads = out[1].to_vec::<f32>()?;
        anyhow::ensure!(grads.len() == self.mm.dim, "grads dim mismatch");
        anyhow::ensure!(loss.is_finite(), "non-finite loss {loss} (diverged?)");
        Ok((loss, grads))
    }

    /// Evaluation: `(params, x, y) → (loss, correct_count)`.
    pub fn eval_step(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        let (x, y) = self.batch_literals(batch)?;
        let p = lit_f32(params, &[self.mm.dim as i64])?;
        let out = run_tuple(&self.eval, &[p, x, y])?;
        anyhow::ensure!(out.len() == 2, "eval artifact returned {} outputs", out.len());
        Ok((out[0].to_vec::<f32>()?[0], out[1].to_vec::<f32>()?[0]))
    }

    /// L1 leader kernel: `(m, g, β) → (idx, vals, m_next)` — Pallas
    /// chunk-top-1 selection + low-pass memory update on-device.
    pub fn kernel_compress(
        &self,
        m: &[f32],
        g: &[f32],
        beta: f32,
    ) -> Result<(Vec<u32>, Vec<f32>, Vec<f32>)> {
        let dim = self.mm.dim as i64;
        let out = run_tuple(
            &self.compress,
            &[
                lit_f32(m, &[dim])?,
                lit_f32(g, &[dim])?,
                xla::Literal::scalar(beta),
            ],
        )?;
        anyhow::ensure!(out.len() == 3, "compress artifact returned {}", out.len());
        let idx: Vec<u32> = out[0].to_vec::<i32>()?.iter().map(|&i| i as u32).collect();
        let vals = out[1].to_vec::<f32>()?;
        let m_next = out[2].to_vec::<f32>()?;
        anyhow::ensure!(idx.len() == self.mm.k && vals.len() == self.mm.k);
        Ok((idx, vals, m_next))
    }

    /// L1 follower kernel: `(m, g, idx, β) → (vals, m_next)`.
    pub fn kernel_apply(
        &self,
        m: &[f32],
        g: &[f32],
        idx: &[u32],
        beta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(idx.len() == self.mm.k, "idx len != k");
        let dim = self.mm.dim as i64;
        let idx_i32: Vec<i32> = idx.iter().map(|&i| i as i32).collect();
        let out = run_tuple(
            &self.apply,
            &[
                lit_f32(m, &[dim])?,
                lit_f32(g, &[dim])?,
                lit_i32(&idx_i32, &[self.mm.k as i64])?,
                xla::Literal::scalar(beta),
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "apply artifact returned {}", out.len());
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<f32>()?))
    }

    pub fn load_init_params(&self) -> Result<Vec<f32>> {
        self.mm.load_init_params()
    }
}
