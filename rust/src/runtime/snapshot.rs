//! Error-feedback memory snapshots — the state half of the
//! reconnect-with-resume contract.
//!
//! ScaleCom's error-feedback memory is the only cross-step state a
//! worker carries (the compressors themselves are stateless per step,
//! and the synthetic gradient stream is a replayable seeded RNG), so a
//! snapshot of `(step, EfMemory)` is a complete resume point: a worker
//! restarted after a fault restores the memory of the last
//! globally-completed step, fast-forwards its gradient RNG by replaying
//! the draws, and continues — producing selections and digests
//! bit-identical to a fault-free run.
//!
//! Two snapshot stores back the socket node driver (`runtime::socket`):
//!
//! - [`SnapshotRing`] — a small in-memory ring of recent steps kept by
//!   every *surviving* node. Live ranks are at most one collective apart,
//!   so a short ring always covers the resume step the post-rendezvous
//!   min-reduce agrees on.
//! - [`save_ring`]/[`load_at`] — an on-disk mirror of that ring (atomic
//!   tmp+rename persist per file) for the *restarted* node, which lost
//!   its in-memory state with its process (`scalecom node
//!   --snapshot-dir`). A ring rather than just the latest snapshot
//!   because the fleet's agreed resume point can trail the restarted
//!   rank's newest persisted step (see [`save_ring`]).
//!
//! ## Wire/disk format (version 1, little-endian)
//!
//! ```text
//! magic  b"SCEF"
//! u32    format version (1)
//! u64    step (the snapshot is the state AFTER this step completed)
//! f32    beta (EF low-pass discount)
//! u64    dim
//! f32×dim  memory values
//! ```

use crate::compress::EfMemory;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"SCEF";
const FORMAT_VERSION: u32 = 1;

/// Default depth of the survivors' in-memory ring. Live ranks stay
/// within one step of each other (collectives are barriers), so even a
/// shallow ring always holds the agreed resume step; 8 leaves slack for
/// future lookahead drivers.
pub const DEFAULT_RING_DEPTH: usize = 8;

/// File name of the persisted latest snapshot inside `--snapshot-dir`.
pub fn snapshot_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("ef_rank{rank}.snap"))
}

/// File name of one retained per-step snapshot inside `--snapshot-dir`
/// (the on-disk mirror of the survivors' in-memory ring).
pub fn snapshot_step_path(dir: &Path, rank: usize, step: u64) -> PathBuf {
    dir.join(format!("ef_rank{rank}_step{step}.snap"))
}

/// Persist the state after `step` both as the rank's latest-pointer file
/// and as a per-step file, pruning the per-step file that falls out of
/// the `DEFAULT_RING_DEPTH` window.
///
/// Why a ring and not just the latest: the fleet's agreed resume point
/// can be one step *behind* a restarted rank's newest snapshot — a
/// killed node's final ring send may never have flushed, leaving a
/// survivor one step short of the dead node's own progress — and an EF
/// memory cannot be rolled backward without the older state.
pub fn save_ring(dir: &Path, rank: usize, step: u64, mem: &EfMemory) -> anyhow::Result<()> {
    save(&snapshot_path(dir, rank), step, mem)?;
    save(&snapshot_step_path(dir, rank, step), step, mem)?;
    if let Some(old) = step.checked_sub(DEFAULT_RING_DEPTH as u64) {
        let _ = std::fs::remove_file(snapshot_step_path(dir, rank, old));
    }
    Ok(())
}

/// Load the snapshot for exactly `step`: the per-step file first, then
/// the latest-pointer file when it happens to hold that step. `Ok(None)`
/// when neither does.
///
/// A corrupt or mislabeled entry is *skipped with a warning*, not fatal:
/// the caller is walking the resume ring, and an older intact entry (or
/// a lower agreed resume step) is always a valid fallback, whereas an
/// error here would kill the rejoining worker a torn file was supposed
/// to protect.
pub fn load_at(dir: &Path, rank: usize, step: u64) -> anyhow::Result<Option<EfMemory>> {
    let per_step = snapshot_step_path(dir, rank, step);
    match load(&per_step) {
        Ok(Some((s, m))) if s == step => return Ok(Some(m)),
        Ok(Some((s, _))) => eprintln!(
            "snapshot: {} holds step {s}, not the step its name declares; skipping it",
            per_step.display()
        ),
        Ok(None) => {}
        Err(e) => eprintln!("snapshot: skipping corrupt entry: {e:#}"),
    }
    match load(&snapshot_path(dir, rank)) {
        Ok(Some((s, m))) if s == step => Ok(Some(m)),
        Ok(_) => Ok(None),
        Err(e) => {
            eprintln!("snapshot: skipping corrupt entry: {e:#}");
            Ok(None)
        }
    }
}

/// The newest resume point this rank can actually decode: the
/// latest-pointer file when intact, else the newest intact per-step ring
/// entry. A corrupt newest snapshot thereby *degrades* the rank's
/// claimed resume step instead of killing the rejoin — the ring
/// min-reduce then settles on a step everyone can restore.
pub fn latest_on_disk(dir: &Path, rank: usize) -> Option<(u64, EfMemory)> {
    let mut best: Option<(u64, EfMemory)> = None;
    match load(&snapshot_path(dir, rank)) {
        Ok(Some(sm)) => best = Some(sm),
        Ok(None) => {}
        Err(e) => eprintln!("snapshot: skipping corrupt entry: {e:#}"),
    }
    let prefix = format!("ef_rank{rank}_step");
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(step) = name
                .strip_prefix(&prefix)
                .and_then(|r| r.strip_suffix(".snap"))
                .and_then(|s| s.parse::<u64>().ok())
            else {
                continue;
            };
            if best.as_ref().map_or(false, |(b, _)| *b >= step) {
                continue;
            }
            match load(&entry.path()) {
                Ok(Some((s, m))) if s == step => best = Some((s, m)),
                Ok(_) => {}
                Err(e) => eprintln!("snapshot: skipping corrupt entry: {e:#}"),
            }
        }
    }
    best
}

/// Serialize one worker's EF state after `step` into the format above.
pub fn encode(step: u64, mem: &EfMemory) -> Vec<u8> {
    let m = mem.memory();
    let mut out = Vec::with_capacity(4 + 4 + 8 + 4 + 8 + m.len() * 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&step.to_le_bytes());
    out.extend_from_slice(&mem.beta().to_le_bytes());
    out.extend_from_slice(&(m.len() as u64).to_le_bytes());
    for v in m {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Take the next `len` bytes of a snapshot, or fail with a message that
/// says which field was cut off and where — no slice index in [`decode`]
/// can panic on a torn file.
fn take<'a>(bytes: &'a [u8], pos: &mut usize, len: usize, what: &str) -> anyhow::Result<&'a [u8]> {
    let end = pos
        .checked_add(len)
        .filter(|&e| e <= bytes.len())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "snapshot truncated at byte {}: {what} needs {len} bytes, {} remain",
                *pos,
                bytes.len().saturating_sub(*pos)
            )
        })?;
    let slice = &bytes[*pos..end];
    *pos = end;
    Ok(slice)
}

/// Inverse of [`encode`]; fully fallible — every read is length-checked,
/// so a truncated, torn, or corrupt file yields a clear error (wrapped
/// with the file name by [`load`]) instead of panicking the rejoining
/// worker. Rejects bad magic, unknown versions, and bodies that don't
/// match the declared dim.
pub fn decode(bytes: &[u8]) -> anyhow::Result<(u64, EfMemory)> {
    let mut pos = 0usize;
    let magic = take(bytes, &mut pos, 4, "magic")?;
    anyhow::ensure!(magic == MAGIC, "snapshot: bad magic (not an EF snapshot)");
    let version = u32::from_le_bytes(take(bytes, &mut pos, 4, "format version")?.try_into().unwrap());
    anyhow::ensure!(
        version == FORMAT_VERSION,
        "snapshot: format version {version} (this build reads {FORMAT_VERSION})"
    );
    let step = u64::from_le_bytes(take(bytes, &mut pos, 8, "step")?.try_into().unwrap());
    let beta = f32::from_le_bytes(take(bytes, &mut pos, 4, "beta")?.try_into().unwrap());
    anyhow::ensure!(
        beta > 0.0 && beta <= 1.0,
        "snapshot: corrupt beta {beta} (must be in (0, 1])"
    );
    let dim64 = u64::from_le_bytes(take(bytes, &mut pos, 8, "dim")?.try_into().unwrap());
    anyhow::ensure!(dim64 >= 1, "snapshot: empty memory");
    let dim: usize = usize::try_from(dim64)
        .ok()
        .filter(|d| d.checked_mul(4).map_or(false, |b| b <= bytes.len()))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "snapshot: header declares dim {dim64}, but only {} bytes follow",
                bytes.len().saturating_sub(pos)
            )
        })?;
    let body = take(bytes, &mut pos, dim * 4, "memory values")?;
    anyhow::ensure!(
        pos == bytes.len(),
        "snapshot: {} trailing bytes after dim {dim} body",
        bytes.len() - pos
    );
    let m: Vec<f32> = body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let mut mem = EfMemory::new(dim, beta);
    mem.set_memory(m);
    Ok((step, mem))
}

/// Atomically persist the snapshot: write to a `.tmp` sibling, then
/// rename over the target, so a crash mid-write never leaves a torn
/// file where the next restart would read it.
pub fn save(path: &Path, step: u64, mem: &EfMemory) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| anyhow::anyhow!("snapshot: create dir {}: {e}", dir.display()))?;
        }
    }
    let tmp = path.with_extension("snap.tmp");
    let bytes = encode(step, mem);
    {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| anyhow::anyhow!("snapshot: create {}: {e}", tmp.display()))?;
        f.write_all(&bytes)
            .map_err(|e| anyhow::anyhow!("snapshot: write {}: {e}", tmp.display()))?;
        f.sync_all()
            .map_err(|e| anyhow::anyhow!("snapshot: sync {}: {e}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path).map_err(|e| {
        anyhow::anyhow!("snapshot: rename {} -> {}: {e}", tmp.display(), path.display())
    })?;
    Ok(())
}

/// Load a persisted snapshot; `Ok(None)` when the file does not exist
/// (a cold start), `Err` on a corrupt or unreadable file.
pub fn load(path: &Path) -> anyhow::Result<Option<(u64, EfMemory)>> {
    let mut f = match std::fs::File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => anyhow::bail!("snapshot: open {}: {e}", path.display()),
    };
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)
        .map_err(|e| anyhow::anyhow!("snapshot: read {}: {e}", path.display()))?;
    let snap = decode(&bytes)
        .map_err(|e| anyhow::anyhow!("snapshot: {} is corrupt: {e:#}", path.display()))?;
    Ok(Some(snap))
}

/// Bounded in-memory ring of recent `(step, EfMemory)` resume points,
/// newest last. Survivors push after every completed step and roll back
/// to whatever step the post-rendezvous min-reduce agrees on.
#[derive(Debug, Clone)]
pub struct SnapshotRing {
    depth: usize,
    entries: VecDeque<(u64, EfMemory)>,
}

impl SnapshotRing {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1, "a snapshot ring needs at least one slot");
        SnapshotRing {
            depth,
            entries: VecDeque::with_capacity(depth),
        }
    }

    /// Record the state after `step` completed; steps must be pushed in
    /// increasing order (the driver pushes once per completed step).
    pub fn push(&mut self, step: u64, mem: EfMemory) {
        if let Some(&(last, _)) = self.entries.back() {
            assert!(step > last, "snapshot ring: step {step} after {last}");
        }
        if self.entries.len() == self.depth {
            self.entries.pop_front();
        }
        self.entries.push_back((step, mem));
    }

    /// The state after `step`, if still retained.
    pub fn get(&self, step: u64) -> Option<&EfMemory> {
        self.entries
            .iter()
            .find(|(s, _)| *s == step)
            .map(|(_, m)| m)
    }

    /// Newest retained step.
    pub fn latest_step(&self) -> Option<u64> {
        self.entries.back().map(|(s, _)| *s)
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop every snapshot newer than `step` (after a rollback the
    /// replayed steps re-push their own snapshots).
    pub fn truncate_after(&mut self, step: u64) {
        while matches!(self.entries.back(), Some(&(s, _)) if s > step) {
            self.entries.pop_back();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem(dim: usize, fill: f32) -> EfMemory {
        let mut m = EfMemory::new(dim, 0.5);
        m.set_memory((0..dim).map(|i| fill + i as f32).collect());
        m
    }

    #[test]
    fn encode_decode_roundtrip_is_bit_exact() {
        let m = mem(17, 0.25);
        let (step, back) = decode(&encode(41, &m)).unwrap();
        assert_eq!(step, 41);
        assert_eq!(back.memory(), m.memory());
        assert_eq!(back.beta(), m.beta());
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(b"short").is_err());
        let mut bad_magic = encode(0, &mem(4, 0.0));
        bad_magic[0] = b'X';
        assert!(decode(&bad_magic).is_err());
        let mut bad_version = encode(0, &mem(4, 0.0));
        bad_version[4] = 99;
        assert!(decode(&bad_version).is_err());
        let mut truncated = encode(0, &mem(4, 0.0));
        truncated.pop();
        assert!(decode(&truncated).is_err());
        let mut oversized = encode(0, &mem(4, 0.0));
        oversized.push(0);
        assert!(decode(&oversized).is_err());
    }

    #[test]
    fn save_load_roundtrip_and_missing_file_is_none() {
        let dir = std::env::temp_dir().join("scalecom_snapshot_test1");
        let _ = std::fs::remove_dir_all(&dir);
        let path = snapshot_path(&dir, 2);
        assert!(load(&path).unwrap().is_none(), "cold start reads None");
        let m = mem(9, 1.5);
        save(&path, 7, &m).unwrap();
        let (step, back) = load(&path).unwrap().unwrap();
        assert_eq!(step, 7);
        assert_eq!(back.memory(), m.memory());
        // overwrite is atomic-by-rename: the newer step wins
        save(&path, 8, &mem(9, 2.5)).unwrap();
        assert_eq!(load(&path).unwrap().unwrap().0, 8);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_ring_retains_a_window_and_looks_up_exact_steps() {
        let dir = std::env::temp_dir().join("scalecom_snapshot_test2");
        let _ = std::fs::remove_dir_all(&dir);
        for s in 0..=(DEFAULT_RING_DEPTH as u64 + 2) {
            save_ring(&dir, 3, s, &mem(4, s as f32)).unwrap();
        }
        let newest = DEFAULT_RING_DEPTH as u64 + 2;
        // Latest pointer tracks the newest step.
        assert_eq!(load(&snapshot_path(&dir, 3)).unwrap().unwrap().0, newest);
        // Exact-step lookups inside the window succeed (including one
        // step behind the newest — the resume-skew case).
        assert_eq!(load_at(&dir, 3, newest - 1).unwrap().unwrap().memory()[0], (newest - 1) as f32);
        assert_eq!(
            load_at(&dir, 3, newest - (DEFAULT_RING_DEPTH as u64 - 1))
                .unwrap()
                .unwrap()
                .memory()[0],
            (newest - (DEFAULT_RING_DEPTH as u64 - 1)) as f32
        );
        // Steps pruned out of the window are gone; other ranks see nothing.
        assert!(load_at(&dir, 3, 0).unwrap().is_none());
        assert!(load_at(&dir, 0, newest).unwrap().is_none());
        // The latest-pointer fallback covers a dir written before the
        // per-step ring existed.
        std::fs::remove_file(snapshot_step_path(&dir, 3, newest)).unwrap();
        assert_eq!(load_at(&dir, 3, newest).unwrap().unwrap().memory()[0], newest as f32);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn decode_fails_cleanly_at_every_header_boundary() {
        // Cut the encoding at and around every field boundary of the
        // 28-byte header (magic|version|step|beta|dim) and one f32 into
        // the body: every prefix must produce an error, never a panic.
        let full = encode(3, &mem(4, 1.0));
        for cut in [0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 19, 20, 21, 27, 28, 31, 32] {
            assert!(cut < full.len());
            let err = decode(&full[..cut]).expect_err(&format!("cut at {cut} must fail"));
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") || msg.contains("dim") || msg.contains("empty"),
                "cut at {cut}: unexpected message: {msg}"
            );
        }
        // one byte short of complete — the classic torn tail
        assert!(decode(&full[..full.len() - 1]).is_err());
        // a dim that promises far more data than the file holds must not
        // allocate or scan past the end
        let mut huge_dim = full.clone();
        huge_dim[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
        let msg = format!("{:#}", decode(&huge_dim).unwrap_err());
        assert!(msg.contains("dim"), "{msg}");
    }

    #[test]
    fn load_at_skips_corrupt_entries_and_continues_down_the_ring() {
        let dir = std::env::temp_dir().join("scalecom_snapshot_test3");
        let _ = std::fs::remove_dir_all(&dir);
        for s in 0..4u64 {
            save_ring(&dir, 1, s, &mem(4, s as f32)).unwrap();
        }
        // Corrupt the newest per-step entry (truncate mid-header): the
        // exact-step lookup falls through to the latest-pointer file,
        // which holds the same step — no error, no panic.
        let newest = snapshot_step_path(&dir, 1, 3);
        std::fs::write(&newest, &encode(3, &mem(4, 3.0))[..13]).unwrap();
        assert_eq!(load_at(&dir, 1, 3).unwrap().unwrap().memory()[0], 3.0);
        // Corrupt the latest pointer too: step 3 is unrecoverable, but
        // the caller gets Ok(None) and walks down to the intact step 2.
        std::fs::write(snapshot_path(&dir, 1), b"garbage").unwrap();
        assert!(load_at(&dir, 1, 3).unwrap().is_none());
        assert_eq!(load_at(&dir, 1, 2).unwrap().unwrap().memory()[0], 2.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_on_disk_degrades_past_corrupt_snapshots() {
        let dir = std::env::temp_dir().join("scalecom_snapshot_test4");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(latest_on_disk(&dir, 0).is_none(), "missing dir is a cold start");
        for s in 0..3u64 {
            save_ring(&dir, 0, s, &mem(4, s as f32)).unwrap();
        }
        assert_eq!(latest_on_disk(&dir, 0).unwrap().0, 2);
        // Corrupt the latest pointer AND the newest per-step file: the
        // claimed resume point degrades to step 1 instead of erroring.
        std::fs::write(snapshot_path(&dir, 0), b"SCEFxxxx").unwrap();
        std::fs::write(snapshot_step_path(&dir, 0, 2), b"").unwrap();
        let (step, m) = latest_on_disk(&dir, 0).unwrap();
        assert_eq!(step, 1);
        assert_eq!(m.memory()[0], 1.0);
        // other ranks' files are never consulted
        assert!(latest_on_disk(&dir, 5).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ring_retains_depth_newest_and_truncates() {
        let mut r = SnapshotRing::new(3);
        assert!(r.is_empty());
        assert_eq!(r.latest_step(), None);
        for s in 0..5u64 {
            r.push(s, mem(4, s as f32));
        }
        assert_eq!(r.latest_step(), Some(4));
        assert!(r.get(1).is_none(), "evicted by depth");
        assert_eq!(r.get(2).unwrap().memory()[0], 2.0);
        r.truncate_after(2);
        assert_eq!(r.latest_step(), Some(2));
        assert!(r.get(3).is_none());
        r.push(3, mem(4, 30.0));
        assert_eq!(r.get(3).unwrap().memory()[0], 30.0);
    }
}
