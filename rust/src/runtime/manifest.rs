//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parsed with the in-repo JSON module and validated
//! hard — schema drift must fail at load time, not mid-training.

use crate::compress::rate::{LayerPartition, LayerSlice};
use crate::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor spec for an artifact input.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => anyhow::bail!("unknown dtype '{other}'"),
        }
    }
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }

    fn from_json(v: &Json) -> anyhow::Result<TensorSpec> {
        let shape = v
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("shape must be an array"))?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("bad shape dim"))
            })
            .collect::<anyhow::Result<Vec<usize>>>()?;
        let dtype = Dtype::parse(
            v.req("dtype")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("dtype must be a string"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One model's manifest entry.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    /// flat parameter/gradient dimension P
    pub dim: usize,
    /// per-worker batch the artifacts were lowered with
    pub batch: usize,
    /// chunk size of the compress artifact (== compression rate)
    pub chunk: usize,
    /// number of selected coordinates K = ceil(P/chunk)
    pub k: usize,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub compress_hlo: PathBuf,
    pub apply_hlo: PathBuf,
    pub init_params: PathBuf,
    pub x: TensorSpec,
    pub y: TensorSpec,
    pub layers: LayerPartition,
}

impl ModelManifest {
    fn from_json(name: &str, v: &Json, dir: &Path) -> anyhow::Result<ModelManifest> {
        let req_usize = |key: &str| -> anyhow::Result<usize> {
            v.req(key)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("field '{key}' must be a non-negative int"))
        };
        let req_path = |key: &str| -> anyhow::Result<PathBuf> {
            Ok(dir.join(
                v.req(key)?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("field '{key}' must be a string"))?,
            ))
        };
        let layers_json = v
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("layers must be an array"))?;
        let mut layers = Vec::with_capacity(layers_json.len());
        for l in layers_json {
            layers.push(LayerSlice {
                name: l
                    .req("name")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("layer name"))?
                    .to_string(),
                offset: l
                    .req("offset")?
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("layer offset"))?,
                len: l
                    .req("len")?
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("layer len"))?,
                flops_per_sample: l
                    .req("flops_per_sample")?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("layer flops"))?,
                compress: l.get("compress").and_then(|b| b.as_bool()).unwrap_or(true),
            });
        }
        let m = ModelManifest {
            name: name.to_string(),
            dim: req_usize("dim")?,
            batch: req_usize("batch")?,
            chunk: req_usize("chunk")?,
            k: req_usize("k")?,
            train_hlo: req_path("train")?,
            eval_hlo: req_path("eval")?,
            compress_hlo: req_path("compress")?,
            apply_hlo: req_path("apply")?,
            init_params: req_path("init_params")?,
            x: TensorSpec::from_json(v.req("x")?)?,
            y: TensorSpec::from_json(v.req("y")?)?,
            layers: LayerPartition::try_from_layers(layers)?,
        };
        m.validate()?;
        Ok(m)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.dim > 0, "dim must be positive");
        anyhow::ensure!(
            self.layers.total_len() == self.dim,
            "layer partition covers {} of {} params",
            self.layers.total_len(),
            self.dim
        );
        anyhow::ensure!(
            self.k == self.dim.div_ceil(self.chunk),
            "k={} inconsistent with dim={} chunk={}",
            self.k,
            self.dim,
            self.chunk
        );
        anyhow::ensure!(
            self.x.shape.first() == Some(&self.batch),
            "x batch dim mismatch"
        );
        Ok(())
    }

    /// Load the initial flat parameters (f32 little-endian).
    pub fn load_init_params(&self) -> anyhow::Result<Vec<f32>> {
        let bytes = std::fs::read(&self.init_params).map_err(|e| {
            anyhow::anyhow!("reading {}: {e}", self.init_params.display())
        })?;
        anyhow::ensure!(
            bytes.len() == self.dim * 4,
            "init params file has {} bytes, expected {} (dim={})",
            bytes.len(),
            self.dim * 4,
            self.dim
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelManifest>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "reading {}: {e} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = Json::parse(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
        let version = v.req("version")?.as_usize().unwrap_or(0);
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let mut models = BTreeMap::new();
        for (name, entry) in v
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("models must be an object"))?
        {
            models.insert(name.clone(), ModelManifest::from_json(name, entry, dir)?);
        }
        anyhow::ensure!(!models.is_empty(), "manifest has no models");
        Ok(Manifest {
            models,
            dir: dir.to_path_buf(),
        })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelManifest> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "model '{name}' not in manifest (have: {})",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> String {
        r#"{
          "version": 1,
          "models": {
            "tiny": {
              "dim": 10, "batch": 2, "chunk": 5, "k": 2,
              "train": "tiny.hlo.txt", "eval": "tiny_eval.hlo.txt",
              "compress": "tiny_c.hlo.txt", "apply": "tiny_a.hlo.txt",
              "init_params": "tiny_init.bin",
              "x": {"shape": [2, 4], "dtype": "f32"},
              "y": {"shape": [2], "dtype": "i32"},
              "layers": [
                {"name": "w", "offset": 0, "len": 8, "flops_per_sample": 16.0},
                {"name": "b", "offset": 8, "len": 2, "flops_per_sample": 0.0}
              ]
            }
          }
        }"#
        .to_string()
    }

    fn write_sample(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest_json()).unwrap();
        let init: Vec<u8> = (0..10u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
        std::fs::write(dir.join("tiny_init.bin"), init).unwrap();
    }

    #[test]
    fn loads_and_validates() {
        let dir = std::env::temp_dir().join("scalecom_manifest_test1");
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.dim, 10);
        assert_eq!(tiny.x.dtype, Dtype::F32);
        assert_eq!(tiny.x.elements(), 8);
        assert_eq!(tiny.x.dims_i64(), vec![2, 4]);
        assert_eq!(tiny.layers.layers.len(), 2);
        let params = tiny.load_init_params().unwrap();
        assert_eq!(params.len(), 10);
        assert_eq!(params[3], 3.0);
        assert!(m.model("missing").is_err());
    }

    #[test]
    fn rejects_bad_layer_cover() {
        let dir = std::env::temp_dir().join("scalecom_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = sample_manifest_json().replace("\"len\": 8", "\"len\": 7");
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_inconsistent_k() {
        let dir = std::env::temp_dir().join("scalecom_manifest_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = sample_manifest_json().replace("\"k\": 2", "\"k\": 3");
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_wrong_init_size() {
        let dir = std::env::temp_dir().join("scalecom_manifest_test4");
        write_sample(&dir);
        std::fs::write(dir.join("tiny_init.bin"), vec![0u8; 12]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("tiny").unwrap().load_init_params().is_err());
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
