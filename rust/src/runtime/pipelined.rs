//! Pipelined backend: a persistent worker pool that double-buffers steps.
//!
//! The threaded backend (PR 1) spawns scoped threads and rebuilds the
//! channel mesh every step, and runs each step's compute strictly before
//! its exchange. This engine spawns every thread **once per run**:
//!
//!   - a **compute lane** per worker — a long-lived thread that *owns*
//!     the worker's `EfMemory` (the coordinator talks to it through the
//!     handle API below) and executes, FIFO: EF gradient, value
//!     extraction forwarding, and the low-pass memory update;
//!   - a **comm lane** per worker (`comm::parallel::CommLanes`) — a
//!     long-lived thread owning the worker's ring and star endpoints,
//!     running the blocking collectives off the compute path.
//!
//! Double-buffering falls out of the lane split: as soon as a compute
//! lane has forwarded step t's payload to its comm lane it applies the
//! memory update and is free to compute step t+1's EF gradient — while
//! step t's ring reduce-scatter/all-gather (or star gather) is still in
//! flight. Because each lane's command queue is FIFO, step t+1's EF
//! gradient always reads exactly the post-step-t memory (the one-step-lag
//! contract, property-tested in `crate::proptest`).
//!
//! Semantics are inside PR 1's determinism contract (locked by
//! `rust/tests/backend_parity.rs`): EF gradients, selections, and memory
//! updates are bit-identical to the sequential backend; the gather-path
//! root reduction is bit-identical; ring-reduced f32 values match within
//! rtol 1e-5 / atol 1e-6; pipelined runs are bit-identical to each other.

use crate::comm::parallel::{CollectiveResult, CommJob, CommLanes, LaneTransport};
use crate::comm::GatherStats;
use crate::compress::{EfMemory, SparseGrad};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Commands a compute lane executes in FIFO order.
enum Cmd {
    /// Start a step: compute `ef = m + grad`, stash `grad` for this
    /// step's memory update, reply with `ef`.
    BeginStep {
        grad: Vec<f32>,
        reply: Sender<Vec<f32>>,
    },
    /// Finish a shared-index step: forward the k selected values into
    /// the ring, then apply the low-pass memory update with the stashed
    /// gradient and the broadcast index set.
    FinishShared { idx: Arc<Vec<u32>>, vals: Vec<f32> },
    /// Finish a per-worker-index step: forward the sparse contribution
    /// to the star, then apply the memory update with its index set.
    FinishGather { sparse: SparseGrad },
    /// Dense (warmup / no-compression) step: forward the full gradient
    /// into the ring; memory is not involved.
    Dense { grad: Vec<f32> },
    /// Start one **bucket** of a bucketed step: compute the EF gradient
    /// for the slice `[offset, offset + grad.len())`, stash the slice
    /// for the bucket's memory update, reply with the slice EF.
    BeginBucket {
        bucket: u32,
        offset: usize,
        grad: Vec<f32>,
        reply: Sender<Vec<f32>>,
    },
    /// Finish a shared-index bucket: forward the bucket-tagged values
    /// into the ring, then apply the memory update on the bucket's
    /// slice (`idx` is bucket-local).
    FinishSharedBucket {
        bucket: u32,
        idx: Arc<Vec<u32>>,
        vals: Vec<f32>,
    },
    /// Finish a per-worker-index bucket: `sparse` is bucket-local
    /// (its `dim` is the bucket length, its indices bucket-relative).
    FinishGatherBucket { bucket: u32, sparse: SparseGrad },
    /// Pure EF-gradient query (trainer hooks, tests) — touches no step
    /// state.
    EfQuery {
        grad: Vec<f32>,
        reply: Sender<Vec<f32>>,
    },
    /// Reply with a clone of the current memory. FIFO ⇒ the snapshot
    /// reflects every step submitted before this command.
    Snapshot { reply: Sender<EfMemory> },
    SetBeta(f32),
}

/// Handle to the persistent worker pool. Owned by the `Coordinator` for
/// the pipelined backend; dropping it drains every queued command (no
/// step is left partially applied), then joins all lane threads.
pub struct WorkerPool {
    cmds: Vec<Sender<Cmd>>,
    lanes: CommLanes,
    compute: Vec<JoinHandle<()>>,
    n: usize,
    dim: usize,
}

impl WorkerPool {
    /// Spawn the pool on the channel-transport mesh, moving each
    /// worker's error-feedback memory into its compute lane.
    pub fn new(memories: Vec<EfMemory>) -> WorkerPool {
        Self::with_transport(memories, LaneTransport::Channel)
            .expect("the channel mesh needs no OS resources and cannot fail")
    }

    /// Spawn the pool with its comm lanes on the chosen transport
    /// (`Backend::Socket` = `LaneTransport::Socket`: a loopback TCP mesh
    /// through the wire codec; mesh setup can fail if the OS refuses the
    /// sockets).
    pub fn with_transport(
        memories: Vec<EfMemory>,
        transport: LaneTransport,
    ) -> anyhow::Result<WorkerPool> {
        let lanes = CommLanes::with_transport(memories.len(), transport)?;
        Ok(Self::with_lanes(memories, lanes))
    }

    /// Spawn the pool on pre-built comm lanes. Splitting mesh
    /// construction (the only fallible part) from lane spawning lets
    /// `Coordinator::try_set_backend` build the mesh *before* moving the
    /// memories, so a failed setup leaves the coordinator untouched.
    pub fn with_lanes(memories: Vec<EfMemory>, lanes: CommLanes) -> WorkerPool {
        let n = memories.len();
        assert!(n >= 1, "worker pool needs at least one worker");
        assert_eq!(lanes.workers(), n, "lanes sized for a different worker count");
        let dim = memories[0].dim();
        assert!(
            memories.iter().all(|m| m.dim() == dim),
            "worker memories must share one dimension"
        );
        let mut cmds = Vec::with_capacity(n);
        let mut compute = Vec::with_capacity(n);
        for (w, mem) in memories.into_iter().enumerate() {
            let (tx, rx) = channel::<Cmd>();
            let job_tx = lanes.job_sender(w);
            compute.push(std::thread::spawn(move || {
                compute_lane_loop(mem, rx, job_tx)
            }));
            cmds.push(tx);
        }
        WorkerPool {
            cmds,
            lanes,
            compute,
            n,
            dim,
        }
    }

    pub fn workers(&self) -> usize {
        self.n
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Entropy-codec counters of the underlying mesh (all-zero on the
    /// channel transport).
    pub fn codec_snapshot(&self) -> crate::comm::codec::CodecSnapshot {
        self.lanes.codec_snapshot()
    }

    fn fan_out_ef(&self, grads: &[Vec<f32>], stash: bool) -> Vec<Vec<f32>> {
        assert_eq!(grads.len(), self.n, "one gradient per worker");
        let replies: Vec<Receiver<Vec<f32>>> = self
            .cmds
            .iter()
            .zip(grads)
            .map(|(tx, g)| {
                let (rtx, rrx) = channel();
                let cmd = if stash {
                    Cmd::BeginStep {
                        grad: g.clone(),
                        reply: rtx,
                    }
                } else {
                    Cmd::EfQuery {
                        grad: g.clone(),
                        reply: rtx,
                    }
                };
                tx.send(cmd).expect("pool command send");
                rrx
            })
            .collect();
        replies
            .iter()
            .map(|r| r.recv().expect("pool ef reply"))
            .collect()
    }

    /// EF gradients `m_i + ∇f_i` on every worker lane (pure query).
    pub fn ef_grads(&self, grads: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.fan_out_ef(grads, false)
    }

    /// Start a compressed step: every lane stashes its gradient for the
    /// upcoming memory update and returns its EF gradient.
    pub fn begin_step(&self, grads: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.fan_out_ef(grads, true)
    }

    /// Finish a shared-index step (CLT-k path): `vals[w]` are worker w's
    /// EF-gradient values at the broadcast indices. Non-blocking — the
    /// ring reduce runs on the comm lanes; collect it with
    /// [`WorkerPool::wait_reduced`].
    pub fn finish_shared(&self, idx: &[u32], vals: Vec<Vec<f32>>) {
        assert_eq!(vals.len(), self.n, "one value set per worker");
        let idx = Arc::new(idx.to_vec());
        for (tx, v) in self.cmds.iter().zip(vals) {
            tx.send(Cmd::FinishShared {
                idx: idx.clone(),
                vals: v,
            })
            .expect("pool command send");
        }
    }

    /// Finish a per-worker-index step (build-up path): `sparses[w]` is
    /// worker w's sparsified contribution. Non-blocking — collect with
    /// [`WorkerPool::wait_gathered`].
    pub fn finish_gather(&self, sparses: Vec<SparseGrad>) {
        assert_eq!(sparses.len(), self.n, "one contribution per worker");
        for (tx, sg) in self.cmds.iter().zip(sparses) {
            tx.send(Cmd::FinishGather { sparse: sg })
                .expect("pool command send");
        }
    }

    /// Dense step: ring all-reduce of the full gradients. Non-blocking —
    /// collect with [`WorkerPool::wait_reduced`].
    pub fn dense_step(&self, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), self.n, "one gradient per worker");
        for (tx, g) in self.cmds.iter().zip(grads) {
            tx.send(Cmd::Dense { grad: g.clone() })
                .expect("pool command send");
        }
    }

    /// Start one bucket of a bucketed step on every lane: each worker's
    /// `grad_slices[w]` covers `[offset, offset + len)` of its gradient.
    /// Returns the per-worker EF-gradient slices. Non-blocking on the
    /// comm side; the EF replies are compute-lane work.
    pub fn begin_bucket(
        &self,
        bucket: u32,
        offset: usize,
        grad_slices: Vec<Vec<f32>>,
    ) -> Vec<Vec<f32>> {
        assert_eq!(grad_slices.len(), self.n, "one gradient slice per worker");
        let replies: Vec<Receiver<Vec<f32>>> = self
            .cmds
            .iter()
            .zip(grad_slices)
            .map(|(tx, grad)| {
                let (rtx, rrx) = channel();
                tx.send(Cmd::BeginBucket {
                    bucket,
                    offset,
                    grad,
                    reply: rtx,
                })
                .expect("pool command send");
                rrx
            })
            .collect();
        replies
            .iter()
            .map(|r| r.recv().expect("pool bucket ef reply"))
            .collect()
    }

    /// Finish a shared-index bucket: `idx_local` is bucket-relative,
    /// `vals[w]` worker w's EF values at those indices. Non-blocking —
    /// the bucket-tagged ring reduce runs on the comm lanes; collect
    /// with [`WorkerPool::try_wait_reduced`] (results arrive in
    /// submission order, echoing the tag).
    pub fn finish_shared_bucket(&self, bucket: u32, idx_local: &[u32], vals: Vec<Vec<f32>>) {
        assert_eq!(vals.len(), self.n, "one value set per worker");
        let idx = Arc::new(idx_local.to_vec());
        for (tx, v) in self.cmds.iter().zip(vals) {
            tx.send(Cmd::FinishSharedBucket {
                bucket,
                idx: idx.clone(),
                vals: v,
            })
            .expect("pool command send");
        }
    }

    /// Finish a per-worker-index bucket: `sparses[w]` is worker w's
    /// bucket-local contribution (dim == bucket length). Non-blocking —
    /// collect with [`WorkerPool::try_wait_gathered`].
    pub fn finish_gather_bucket(&self, bucket: u32, sparses: Vec<SparseGrad>) {
        assert_eq!(sparses.len(), self.n, "one contribution per worker");
        for (tx, sg) in self.cmds.iter().zip(sparses) {
            tx.send(Cmd::FinishGatherBucket { bucket, sparse: sg })
                .expect("pool command send");
        }
    }

    /// Wait for the oldest in-flight ring collective (shared, bucketed
    /// or dense), returning its bucket tag and reduced values. A
    /// `Failed` lane result — only the socket transport can produce one:
    /// a dead, wedged, or mis-framed peer — surfaces as an `anyhow`
    /// error, which `Coordinator::try_step` propagates so `train
    /// --backend socket` fails cleanly instead of panicking.
    pub fn try_wait_reduced(&self) -> anyhow::Result<(u32, Vec<f32>)> {
        match self.lanes.wait() {
            CollectiveResult::Reduced { job: _, bucket, vals } => Ok((bucket, vals)),
            CollectiveResult::Gathered { .. } => {
                panic!("expected a ring result, got a gather result")
            }
            CollectiveResult::Failed(e) => {
                anyhow::bail!("collective failed on a comm lane: {e}")
            }
        }
    }

    /// Wait for the oldest in-flight star gather (same fault contract as
    /// [`WorkerPool::try_wait_reduced`]).
    pub fn try_wait_gathered(&self) -> anyhow::Result<(u32, Vec<f32>, GatherStats)> {
        match self.lanes.wait() {
            CollectiveResult::Gathered {
                job: _,
                bucket,
                vals,
                stats,
            } => Ok((bucket, vals, stats)),
            CollectiveResult::Reduced { .. } => {
                panic!("expected a gather result, got a ring result")
            }
            CollectiveResult::Failed(e) => {
                anyhow::bail!("collective failed on a comm lane: {e}")
            }
        }
    }

    /// Infallible monolithic wrapper of [`WorkerPool::try_wait_reduced`]
    /// for tests/benches that drive the pool directly (channel lanes
    /// cannot fail).
    pub fn wait_reduced(&self) -> Vec<f32> {
        let (bucket, vals) = self
            .try_wait_reduced()
            .expect("loopback socket collective failed");
        debug_assert_eq!(bucket, 0, "monolithic collectives carry bucket 0");
        vals
    }

    /// Infallible monolithic wrapper of [`WorkerPool::try_wait_gathered`].
    pub fn wait_gathered(&self) -> (Vec<f32>, GatherStats) {
        let (bucket, vals, stats) = self
            .try_wait_gathered()
            .expect("loopback socket collective failed");
        debug_assert_eq!(bucket, 0, "monolithic collectives carry bucket 0");
        (vals, stats)
    }

    /// Clone every worker's memory out of its lane. FIFO with respect to
    /// step commands: the snapshot reflects all steps submitted before
    /// this call, even ones whose collective is still in flight.
    pub fn snapshot(&self) -> Vec<EfMemory> {
        let replies: Vec<Receiver<EfMemory>> = self
            .cmds
            .iter()
            .map(|tx| {
                let (rtx, rrx) = channel();
                tx.send(Cmd::Snapshot { reply: rtx })
                    .expect("pool command send");
                rrx
            })
            .collect();
        replies
            .iter()
            .map(|r| r.recv().expect("pool snapshot reply"))
            .collect()
    }

    /// Change β on every worker's memory (takes effect after every step
    /// already submitted, before any step submitted later).
    pub fn set_beta(&self, beta: f32) {
        assert!(
            beta > 0.0 && beta <= 1.0,
            "discount factor β must be in (0, 1], got {beta}"
        );
        for tx in &self.cmds {
            tx.send(Cmd::SetBeta(beta)).expect("pool command send");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Close the command queues; each compute lane drains what is
        // already enqueued (finishing any submitted step's update — no
        // partial application), then exits, dropping its comm-job
        // sender. `self.lanes` drops afterwards and joins the comm
        // threads once their queues drain too.
        self.cmds.clear();
        for h in self.compute.drain(..) {
            let _ = h.join();
        }
    }
}

fn compute_lane_loop(mut mem: EfMemory, rx: Receiver<Cmd>, job_tx: Sender<CommJob>) {
    // This step's gradient, held between BeginStep and Finish*.
    let mut stash: Option<Vec<f32>> = None;
    // Bucketed steps: (bucket, offset, grad slice) triplets, one per
    // in-flight bucket. Begin/Finish pairs arrive FIFO per bucket and
    // buckets are submitted in a fixed order, so a queue suffices; the
    // tags are asserted on pop to catch a desynchronized driver.
    let mut bucket_stash: VecDeque<(u32, usize, Vec<f32>)> = VecDeque::new();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::BeginStep { grad, reply } => {
                let ef = mem.ef_grad(&grad);
                stash = Some(grad);
                let _ = reply.send(ef);
            }
            Cmd::EfQuery { grad, reply } => {
                let _ = reply.send(mem.ef_grad(&grad));
            }
            Cmd::FinishShared { idx, vals } => {
                // Forward first so the collective starts while this lane
                // applies the memory update (Eqn. 5) — the update depends
                // only on (grad, idx), never on the reduced values.
                job_tx
                    .send(CommJob::RingAvg { job: 0, bucket: 0, buf: vals })
                    .expect("comm lane send");
                let grad = stash.take().expect("FinishShared without BeginStep");
                mem.update_after_send(&grad, idx.as_slice());
            }
            Cmd::FinishGather { sparse } => {
                let idx = sparse.indices.clone();
                job_tx
                    .send(CommJob::Gather { job: 0, bucket: 0, sparse })
                    .expect("comm lane send");
                let grad = stash.take().expect("FinishGather without BeginStep");
                mem.update_after_send(&grad, &idx);
            }
            Cmd::Dense { grad } => {
                job_tx
                    .send(CommJob::RingAvg { job: 0, bucket: 0, buf: grad })
                    .expect("comm lane send");
            }
            Cmd::BeginBucket {
                bucket,
                offset,
                grad,
                reply,
            } => {
                let ef = mem.ef_grad_range(offset, &grad);
                bucket_stash.push_back((bucket, offset, grad));
                let _ = reply.send(ef);
            }
            Cmd::FinishSharedBucket { bucket, idx, vals } => {
                // Forward first (the overlap), then the slice update —
                // disjoint buckets commute, so per-bucket updates leave
                // exactly the monolithic memory.
                job_tx
                    .send(CommJob::RingAvg { job: 0, bucket, buf: vals })
                    .expect("comm lane send");
                let (b, offset, grad) = bucket_stash
                    .pop_front()
                    .expect("FinishSharedBucket without BeginBucket");
                assert_eq!(b, bucket, "bucket finish out of order");
                mem.update_after_send_range(offset, &grad, idx.as_slice());
            }
            Cmd::FinishGatherBucket { bucket, sparse } => {
                let idx = sparse.indices.clone();
                job_tx
                    .send(CommJob::Gather { job: 0, bucket, sparse })
                    .expect("comm lane send");
                let (b, offset, grad) = bucket_stash
                    .pop_front()
                    .expect("FinishGatherBucket without BeginBucket");
                assert_eq!(b, bucket, "bucket finish out of order");
                mem.update_after_send_range(offset, &grad, &idx);
            }
            Cmd::Snapshot { reply } => {
                let _ = reply.send(mem.clone());
            }
            Cmd::SetBeta(beta) => mem.set_beta(beta),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Fabric, FabricConfig};
    use crate::compress::sparsify;
    use crate::util::floats::allclose;
    use crate::util::rng::Rng;

    fn rand_grads(seed: u64, n: usize, dim: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut v = vec![0.0; dim];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    fn pool_of(n: usize, dim: usize, beta: f32) -> WorkerPool {
        WorkerPool::new((0..n).map(|_| EfMemory::new(dim, beta)).collect())
    }

    #[test]
    fn pool_ef_grads_match_sequential() {
        for n in [1usize, 2, 5] {
            let dim = 37;
            let grads = rand_grads(n as u64, n, dim);
            let mut memories: Vec<EfMemory> =
                (0..n).map(|_| EfMemory::new(dim, 0.5)).collect();
            for (m, g) in memories.iter_mut().zip(&grads) {
                m.update_after_send(g, &[0, 3]);
            }
            let seq: Vec<Vec<f32>> = memories
                .iter()
                .zip(&grads)
                .map(|(m, g)| m.ef_grad(g))
                .collect();
            let pool = WorkerPool::new(memories);
            let par = pool.ef_grads(&grads);
            // per-worker math, no cross-worker reduction → bit-identical
            assert_eq!(seq, par);
        }
    }

    #[test]
    fn pool_shared_exchange_matches_sequential_reference() {
        let n = 4;
        let dim = 64;
        let k = 8;
        let grads = rand_grads(11, n, dim);
        let pool = pool_of(n, dim, 0.25);
        let mut mem_seq: Vec<EfMemory> =
            (0..n).map(|_| EfMemory::new(dim, 0.25)).collect();

        let efs = pool.begin_step(&grads);
        let idx = crate::util::select::top_k_indices_by_magnitude(&efs[0], k);
        let vals: Vec<Vec<f32>> = efs
            .iter()
            .map(|ef| idx.iter().map(|&i| ef[i as usize]).collect())
            .collect();
        pool.finish_shared(&idx, vals);
        let reduced = pool.wait_reduced();

        // reference: sequential sum + per-worker update
        let mut expect = vec![0.0f32; k];
        for ef in &efs {
            for (e, &i) in expect.iter_mut().zip(&idx) {
                *e += ef[i as usize];
            }
        }
        expect.iter_mut().for_each(|v| *v /= n as f32);
        for (mem, g) in mem_seq.iter_mut().zip(&grads) {
            mem.update_after_send(g, &idx);
        }
        assert!(allclose(&reduced, &expect, 1e-5, 1e-6).is_ok());
        for (a, b) in pool.snapshot().iter().zip(&mem_seq) {
            assert_eq!(a.memory(), b.memory(), "memory updates are per-worker");
        }
    }

    #[test]
    fn pool_gather_is_bit_identical_to_fabric_reduction() {
        let n = 5;
        let dim = 48;
        let grads = rand_grads(13, n, dim);
        let pool = pool_of(n, dim, 1.0);
        let efs = pool.begin_step(&grads);
        let per: Vec<Vec<u32>> = efs
            .iter()
            .map(|ef| crate::util::select::top_k_indices_by_magnitude(ef, 6))
            .collect();
        let sparses: Vec<SparseGrad> = efs
            .iter()
            .zip(&per)
            .map(|(ef, idx)| sparsify(ef, idx))
            .collect();
        pool.finish_gather(sparses.clone());
        let (avg, gs) = pool.wait_gathered();

        let mut fabric = Fabric::new(FabricConfig {
            workers: n,
            ..FabricConfig::default()
        });
        let expect = fabric.sparse_gather_avg(&sparses);
        // same reduction order, same arithmetic → exactly equal
        assert_eq!(avg, expect);
        assert_eq!(gs, GatherStats::from_sparses(&sparses));
    }

    #[test]
    fn pool_double_buffers_two_steps_without_waiting() {
        // Submit step 0 and step 1 fully (step 1's EF gradients read the
        // post-step-0 memory) before collecting either result — the
        // double-buffer the pipelined coordinator runs on.
        let n = 3;
        let dim = 24;
        let k = 4;
        let pool = pool_of(n, dim, 1.0);
        let mut mem_seq: Vec<EfMemory> =
            (0..n).map(|_| EfMemory::new(dim, 1.0)).collect();
        let mut expected_rounds = Vec::new();
        for t in 0..2u64 {
            let grads = rand_grads(100 + t, n, dim);
            let efs = pool.begin_step(&grads);
            // sequential reference for this round
            let efs_seq: Vec<Vec<f32>> = mem_seq
                .iter()
                .zip(&grads)
                .map(|(m, g)| m.ef_grad(g))
                .collect();
            assert_eq!(efs, efs_seq, "t={t}: EF must read post-previous-step memory");
            let idx = crate::util::select::top_k_indices_by_magnitude(&efs[0], k);
            let vals: Vec<Vec<f32>> = efs
                .iter()
                .map(|ef| idx.iter().map(|&i| ef[i as usize]).collect())
                .collect();
            let mut expect = vec![0.0f32; k];
            for ef in &efs {
                for (e, &i) in expect.iter_mut().zip(&idx) {
                    *e += ef[i as usize];
                }
            }
            expect.iter_mut().for_each(|v| *v /= n as f32);
            expected_rounds.push(expect);
            pool.finish_shared(&idx, vals);
            for (mem, g) in mem_seq.iter_mut().zip(&grads) {
                mem.update_after_send(g, &idx);
            }
        }
        // both collectives complete, in submission order
        for expect in &expected_rounds {
            let got = pool.wait_reduced();
            assert!(allclose(&got, expect, 1e-5, 1e-6).is_ok());
        }
    }

    #[test]
    fn bucketed_pool_commands_tile_to_the_monolithic_step() {
        // Drive one step as two buckets (backward order, both collectives
        // in flight before either wait) and as one monolithic step: the
        // memories must be bit-identical, the reduced values must agree
        // within the ring reduction-order tolerance, and results must
        // come back in submission order with their tags.
        let n = 3;
        let dim = 40;
        let split = 24; // bucket 0 = [0, 24), bucket 1 = [24, 40)
        let k = 4;
        let grads = rand_grads(21, n, dim);
        let bucketed = pool_of(n, dim, 0.5);
        let mono = pool_of(n, dim, 0.5);

        // monolithic reference
        let efs = mono.begin_step(&grads);
        let idx_global = {
            let mut lo = crate::util::select::top_k_indices_by_magnitude(&efs[0][..split], k);
            let hi = crate::util::select::top_k_indices_by_magnitude(&efs[0][split..], k);
            lo.extend(hi.iter().map(|&i| i + split as u32));
            lo
        };
        let vals: Vec<Vec<f32>> = efs
            .iter()
            .map(|ef| idx_global.iter().map(|&i| ef[i as usize]).collect())
            .collect();
        mono.finish_shared(&idx_global, vals);
        let mono_reduced = mono.wait_reduced();

        // bucketed: submit bucket 1 then bucket 0 (backward order)
        let spans = [(0usize, split), (split, dim)];
        for &b in &[1usize, 0] {
            let (lo, hi) = spans[b];
            let slices: Vec<Vec<f32>> = grads.iter().map(|g| g[lo..hi].to_vec()).collect();
            let befs = bucketed.begin_bucket(b as u32, lo, slices);
            for (w, ef) in befs.iter().enumerate() {
                assert_eq!(ef.as_slice(), &efs[w][lo..hi], "bucket EF == sliced EF");
            }
            let idx_local: Vec<u32> = idx_global
                .iter()
                .filter(|&&i| (i as usize) >= lo && (i as usize) < hi)
                .map(|&i| i - lo as u32)
                .collect();
            let bvals: Vec<Vec<f32>> = befs
                .iter()
                .map(|ef| idx_local.iter().map(|&i| ef[i as usize]).collect())
                .collect();
            bucketed.finish_shared_bucket(b as u32, &idx_local, bvals);
        }
        // results arrive in submission order, tags echoed
        let (tag1, red1) = bucketed.try_wait_reduced().unwrap();
        let (tag0, red0) = bucketed.try_wait_reduced().unwrap();
        assert_eq!((tag1, tag0), (1, 0));
        let mut stitched = red0;
        stitched.extend(red1);
        assert!(allclose(&stitched, &mono_reduced, 1e-5, 1e-6).is_ok());
        // per-bucket slice updates leave exactly the monolithic memory
        for (a, b) in bucketed.snapshot().iter().zip(&mono.snapshot()) {
            assert_eq!(a.memory(), b.memory(), "bucketed memory must tile exactly");
        }
    }

    #[test]
    fn pool_drop_with_result_in_flight_does_not_hang() {
        let n = 4;
        let dim = 16;
        let pool = pool_of(n, dim, 1.0);
        let grads = rand_grads(7, n, dim);
        let efs = pool.begin_step(&grads);
        let idx: Vec<u32> = vec![0, 5];
        let vals: Vec<Vec<f32>> = efs
            .iter()
            .map(|ef| idx.iter().map(|&i| ef[i as usize]).collect())
            .collect();
        pool.finish_shared(&idx, vals);
        // snapshot (queued after the finish) must show the applied update
        let snap = pool.snapshot();
        let mut mem_seq = EfMemory::new(dim, 1.0);
        mem_seq.update_after_send(&grads[0], &idx);
        assert_eq!(snap[0].memory(), mem_seq.memory());
        drop(pool); // reduced values never collected — drop must drain cleanly
    }

    #[test]
    fn pool_set_beta_applies_between_steps() {
        let pool = pool_of(2, 8, 1.0);
        pool.set_beta(0.5);
        let snap = pool.snapshot();
        assert!(snap.iter().all(|m| (m.beta() - 0.5).abs() < 1e-6));
    }
}
