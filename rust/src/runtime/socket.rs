//! Multi-process socket runtime: rendezvous, the per-node coordination
//! driver behind `scalecom node`, and the parity digest.
//!
//! One `scalecom` binary runs an N-process ring on localhost or N hosts:
//!
//! ```text
//! scalecom node --role coordinator --bind 127.0.0.1:7400 \
//!     --peers 127.0.0.1:7400,127.0.0.1:7401,127.0.0.1:7402,127.0.0.1:7403
//! scalecom node --role worker --bind 127.0.0.1:7401 --peers <same list>
//! ... (one process per peer)
//! ```
//!
//! Every node gets the same `--peers` list (rank = position of its own
//! `--bind` in it; the coordinator is rank 0) and runs the same
//! deterministic synthetic coordination workload — the per-step
//! protocol of Algorithm 1 with the collectives on real TCP
//! (`comm::socket`): EF gradient, selection (the CLT-k leader broadcasts
//! its index set around the ring), ring all-reduce of the selected
//! values or star gather of per-worker sparse sets, low-pass memory
//! update.
//!
//! ## The parity digest
//!
//! The coordinator books every collective through the same
//! `Fabric::record_*` entry points as the in-process backends and emits
//! a line-oriented **digest** on stdout: per step, the leader, the index
//! selection, the reduced values at the transmitted coordinates, and
//! the booked `CommCost`; at the end, rank 0's error-feedback memory.
//! [`sequential_digest`] produces the same structure from an in-process
//! sequential `Coordinator` run over the identical gradient stream, and
//! [`compare_digests`] holds the two to the backend parity contract
//! (selections/leaders/`CommCost` exact, gather values bit-identical,
//! ring-reduced f32 within rtol/atol) — that is what
//! `rust/tests/socket_multiprocess.rs` asserts over 4 real processes.
//!
//! Faults are part of the contract: every socket wait is bounded (read
//! timeouts + EOF on peer death), so killing a worker mid-run surfaces
//! as a clean `anyhow` error on every surviving node — never a hang.
//!
//! ## Fault tolerance: heartbeat + reconnect-with-resume
//!
//! With `--heartbeat-ms` the mesh carries wire-level liveness
//! (`Ping`/`Pong` control frames): a dead or wedged peer is detected
//! within 2× the interval instead of only at the next blocking read.
//! With `--reconnect` a link fault no longer ends the run: the node
//! drops its mesh (the EOFs cascade the recovery wave to peers still
//! blocked mid-collective), re-runs rendezvous on the **same** listener,
//! and the re-formed ring agrees on a resume point with a `Resume`
//! min-reduce — every node reports the newest step its snapshot can
//! restore (`0` = from scratch; survivors keep a short in-memory ring of
//! recent `EfMemory` snapshots, a restarted process reloads from the
//! on-disk ring it persisted under `--snapshot-dir` — a ring, because
//! the fleet minimum can trail its own newest snapshot when the dead
//! node's final ring send never flushed), and the fleet minimum wins. Each
//! node rolls its EF memory back to that step, fast-forwards a fresh
//! gradient RNG past the replayed prefix, and continues. Because the
//! compressors are stateless per step and the EF memory is the only
//! cross-step state, the replayed selections/values are **bit-identical**
//! to a fault-free run — rank 0 re-emits the replayed digest lines
//! (superseding its pre-fault emissions; [`parse_digest`] keeps the
//! replay), so a kill+rejoin run's digest equals the fault-free digest.

use crate::comm::socket::form_mesh_with;
use crate::comm::{CommCost, Fabric, FabricConfig, Topology};
use crate::compress::{schemes::make_compressor, sparsify, Compressor, EfMemory, Selection};
use crate::coordinator::{Coordinator, Mode};
use crate::runtime::snapshot::{self, SnapshotRing};
use crate::util::rng::Rng;
use std::io::Write;
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Which side of the rendezvous this process is. Rank 0 — first in
/// `--peers` — is the coordinator: it roots the gather star and emits
/// the digest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Coordinator,
    Worker,
}

impl Role {
    pub fn parse(s: &str) -> anyhow::Result<Role> {
        match s {
            "coordinator" | "coord" => Ok(Role::Coordinator),
            "worker" => Ok(Role::Worker),
            other => anyhow::bail!("unknown role '{other}' (expected coordinator|worker)"),
        }
    }
}

/// A validated node identity: who we are, where we listen, who the
/// peers are. Built by [`NodeSpec::from_flags`], which turns every
/// misconfiguration — most importantly a missing `--peers` — into a
/// clear `anyhow` error instead of a panic.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub role: Role,
    pub bind: String,
    /// Every node's bind address, coordinator first; identical on every
    /// node (rank = index of `bind` in it).
    pub peers: Vec<String>,
    pub rank: usize,
    pub timeout: Duration,
    /// Wire entropy-codec configuration of this node's mesh endpoints.
    /// Must match across nodes that enable packing (the `Hello`
    /// handshake rejects a peer that cannot decode packed frames).
    pub wire_codec: crate::comm::WireCodecConfig,
    /// Heartbeat interval of the mesh's liveness machinery (None = no
    /// heartbeats; faults are detected only at blocking reads). Must be
    /// set on every node or none (the `Hello` handshake rejects a
    /// heartbeat-less peer on a heartbeat mesh).
    pub heartbeat: Option<Duration>,
    /// Reconnect-with-resume after a link fault instead of failing the
    /// run (see the module docs for the protocol).
    pub reconnect: bool,
    /// How many link faults this node will recover from before giving up
    /// (guards against reconnect storms on a genuinely broken fleet).
    pub max_reconnect_attempts: usize,
    /// Where to persist the on-disk EF-memory snapshot ring after every
    /// step (atomic tmp+rename per file), so a restarted process can
    /// rejoin and resume even when the fleet's agreed step trails its
    /// own newest snapshot. Per-run scratch — reusing a previous run's
    /// directory makes the resume min-reduce see stale steps.
    pub snapshot_dir: Option<PathBuf>,
    /// Hierarchical ring-of-rings group size (0/1 = flat ring): ranks
    /// are tiled into consecutive groups of this many members, dense
    /// traffic runs intra-ring + leader uplink ring + downlink
    /// broadcast. Must match on every node (the rendezvous classifies
    /// hello purposes per topology and rejects a mixed fleet) and tile
    /// the node count (`comm::parallel::validate_group_size`).
    pub group_size: usize,
    /// Graceful-drain mode: poll the process-wide shutdown flag
    /// ([`crate::util::signal`]) at every step boundary via a one-frame
    /// ring ballot, and when any rank has seen SIGINT/SIGTERM the whole
    /// fleet drains at the *same* boundary — in-flight steps complete,
    /// rank 0 still emits a parseable digest tail, and the mesh closes
    /// with clean EOFs instead of RSTs. Must match on every node (a
    /// ballot-less peer reads the ballot frame as a mis-framed stream),
    /// which is why it defaults off and only the CLI entry points turn
    /// it on.
    pub graceful: bool,
}

/// Default reconnect budget: enough for a worker restart plus the EOF
/// cascade it triggers, small enough that a flapping fleet still fails.
pub const DEFAULT_RECONNECT_ATTEMPTS: usize = 3;

/// `SCALECOM_HEARTBEAT_MS`: default heartbeat interval for `scalecom
/// node` when no `--heartbeat-ms` flag is given (flag wins; `0` = off).
/// Set-but-invalid is a loud error, never a silent fallback — the same
/// contract as `SCALECOM_WIRE_COMPRESSION`.
pub const ENV_HEARTBEAT_MS: &str = "SCALECOM_HEARTBEAT_MS";

/// Read [`ENV_HEARTBEAT_MS`]; `Ok(None)` when unset.
pub fn env_heartbeat_ms() -> anyhow::Result<Option<u64>> {
    match std::env::var(ENV_HEARTBEAT_MS) {
        Ok(s) => s.trim().parse::<u64>().map(Some).map_err(|_| {
            anyhow::anyhow!(
                "{ENV_HEARTBEAT_MS}={s}: expects a whole number of milliseconds (0 = off)"
            )
        }),
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(e) => Err(anyhow::anyhow!("{ENV_HEARTBEAT_MS}: {e}")),
    }
}

impl NodeSpec {
    pub fn from_flags(
        role: Option<&str>,
        bind: Option<&str>,
        peers: Option<&str>,
        timeout: Duration,
    ) -> anyhow::Result<NodeSpec> {
        let role = Role::parse(role.ok_or_else(|| {
            anyhow::anyhow!("the socket runtime needs --role coordinator|worker")
        })?)?;
        let peers_str = peers.ok_or_else(|| {
            anyhow::anyhow!(
                "the socket runtime needs --peers: a comma-separated list of every \
                 node's address with the coordinator first, identical on every node \
                 (e.g. --peers 127.0.0.1:7400,127.0.0.1:7401)"
            )
        })?;
        let peers: Vec<String> = peers_str
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        anyhow::ensure!(!peers.is_empty(), "--peers lists no addresses");
        for (i, a) in peers.iter().enumerate() {
            anyhow::ensure!(
                !peers[..i].contains(a),
                "--peers lists '{a}' twice (every node needs its own address)"
            );
        }
        let bind = bind
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "the socket runtime needs --bind: this node's own address, \
                     which must appear in --peers"
                )
            })?
            .trim()
            .to_string();
        let rank = peers.iter().position(|p| p == &bind).ok_or_else(|| {
            anyhow::anyhow!(
                "--bind {bind} does not appear in --peers [{}] — every node's bind \
                 address must be listed so ranks are well-defined",
                peers.join(", ")
            )
        })?;
        match role {
            Role::Coordinator => anyhow::ensure!(
                rank == 0,
                "the coordinator must be first in --peers, but --bind {bind} is \
                 entry {rank}"
            ),
            Role::Worker => anyhow::ensure!(
                rank != 0,
                "--bind {bind} is first in --peers, which makes this node the \
                 coordinator — launch it with --role coordinator"
            ),
        }
        Ok(NodeSpec {
            role,
            bind,
            peers,
            rank,
            timeout,
            wire_codec: crate::comm::WireCodecConfig::default(),
            heartbeat: None,
            reconnect: false,
            max_reconnect_attempts: DEFAULT_RECONNECT_ATTEMPTS,
            snapshot_dir: None,
            group_size: 0,
            graceful: false,
        })
    }

    /// Set the hierarchical ring-of-rings group size (builder style;
    /// 0 = flat ring). Validated against the node count here, so a bad
    /// tiling fails at launch instead of at rendezvous.
    pub fn with_group_size(mut self, group_size: usize) -> anyhow::Result<NodeSpec> {
        crate::comm::parallel::validate_group_size(self.workers(), group_size)?;
        self.group_size = group_size;
        Ok(self)
    }

    /// Set the wire entropy-codec configuration (builder style, applied
    /// after [`NodeSpec::from_flags`]).
    pub fn with_wire_codec(mut self, cfg: crate::comm::WireCodecConfig) -> NodeSpec {
        self.wire_codec = cfg;
        self
    }

    /// Configure the fault-tolerance policy (builder style): the
    /// heartbeat interval, whether to reconnect-and-resume after a link
    /// fault, and where to persist the EF-memory snapshot a restarted
    /// process resumes from.
    pub fn with_fault_tolerance(
        mut self,
        heartbeat: Option<Duration>,
        reconnect: bool,
        snapshot_dir: Option<PathBuf>,
    ) -> NodeSpec {
        self.heartbeat = heartbeat;
        self.reconnect = reconnect;
        self.snapshot_dir = snapshot_dir;
        self
    }

    /// Enable the graceful SIGINT/SIGTERM drain ballot (builder style).
    /// Fleet-wide setting: turn it on for every node or none.
    pub fn with_graceful(mut self, graceful: bool) -> NodeSpec {
        self.graceful = graceful;
        self
    }

    pub fn workers(&self) -> usize {
        self.peers.len()
    }
}

/// The deterministic synthetic coordination workload every node runs —
/// the knobs of the backend-parity harness, CLI-settable.
#[derive(Debug, Clone)]
pub struct NodeWorkload {
    pub scheme: String,
    pub dim: usize,
    pub rate: usize,
    pub steps: usize,
    pub warmup: usize,
    pub seed: u64,
    pub beta: f32,
    pub topology: Topology,
    /// Artificial per-step delay (fault-injection tests use it to hold
    /// a run open long enough to kill a worker mid-run).
    pub step_delay_ms: u64,
}

impl Default for NodeWorkload {
    fn default() -> Self {
        NodeWorkload {
            scheme: "scalecom".into(),
            dim: 96,
            rate: 8,
            steps: 50,
            warmup: 0,
            seed: 42,
            beta: 0.5,
            topology: Topology::Ring,
            step_delay_ms: 0,
        }
    }
}

/// Schemes whose selection is computable from what a real node can see
/// (its own EF gradient, plus the leader's broadcast index set). The
/// oracle/tree schemes (true-topk, gtop-k, sketch-k) need cross-worker
/// dense state the wire protocol does not carry.
const SUPPORTED_SCHEMES: &[&str] = &[
    "none",
    "scalecom",
    "clt-k",
    "scalecom-exact",
    "clt-k-exact",
    "random-k",
    "local-topk",
    "local-topk-chunk",
];

impl NodeWorkload {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.dim >= 1, "--dim must be >= 1");
        anyhow::ensure!(self.rate >= 1, "--rate must be >= 1");
        anyhow::ensure!(self.steps >= 1, "--steps must be >= 1");
        anyhow::ensure!(
            self.beta > 0.0 && self.beta <= 1.0,
            "--beta must be in (0, 1]"
        );
        anyhow::ensure!(
            SUPPORTED_SCHEMES.contains(&self.scheme.as_str()),
            "scheme '{}' is not runnable on the multi-process socket driver (its \
             selection needs cross-worker dense state); supported: {}",
            self.scheme,
            SUPPORTED_SCHEMES.join("|")
        );
        Ok(())
    }

    /// The per-step sparse budget the compression rate implies. Public
    /// because the serve job runner replays the exact coordinator
    /// construction (`Coordinator::new(.., wl.k(), ..)`) for digest
    /// parity with one-shot runs.
    pub fn k(&self) -> usize {
        (self.dim / self.rate).max(1)
    }
}

// ----------------------------------------------------------------------
// Digest: what a run did, comparable across implementations
// ----------------------------------------------------------------------

/// One step's exchange shape.
#[derive(Debug, Clone, PartialEq)]
pub enum StepKind {
    /// Dense warmup / no-compression all-reduce.
    Dense,
    /// Shared-index sparse all-reduce (the broadcast index set).
    Shared(Vec<u32>),
    /// Per-worker gather (each worker's index set, worker order).
    Gather(Vec<Vec<u32>>),
}

/// One step of the digest: everything the parity contract constrains.
#[derive(Debug, Clone)]
pub struct StepDigest {
    pub t: usize,
    pub leader: usize,
    pub kind: StepKind,
    /// The reduced values at the transmitted coordinates: the full dense
    /// average for `Dense`, the k reduced values (index order) for
    /// `Shared`, the averaged values at the sorted union for `Gather`.
    pub values: Vec<f32>,
    pub comm: CommCost,
}

/// A whole run's digest, as emitted by the coordinator (rank 0).
#[derive(Debug, Clone)]
pub struct NodeDigest {
    pub workers: usize,
    pub steps: Vec<StepDigest>,
    /// Rank 0's final error-feedback memory.
    pub final_memory_rank0: Vec<f32>,
}

fn fmt_f32s(vals: &[f32]) -> String {
    if vals.is_empty() {
        return "-".into();
    }
    vals.iter()
        .map(|v| format!("{v}"))
        .collect::<Vec<_>>()
        .join(",")
}

fn fmt_u32s(vals: &[u32]) -> String {
    if vals.is_empty() {
        return "-".into();
    }
    vals.iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join(",")
}

fn parse_f32s(s: &str) -> anyhow::Result<Vec<f32>> {
    if s == "-" {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|v| {
            v.parse::<f32>()
                .map_err(|_| anyhow::anyhow!("digest: bad f32 '{v}'"))
        })
        .collect()
}

fn parse_u32s(s: &str) -> anyhow::Result<Vec<u32>> {
    if s == "-" {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|v| {
            v.parse::<u32>()
                .map_err(|_| anyhow::anyhow!("digest: bad u32 '{v}'"))
        })
        .collect()
}

/// Map a parsed op name back to the `&'static str` the fabric uses.
fn op_static(name: &str) -> anyhow::Result<&'static str> {
    Ok(match name {
        "dense_allreduce" => "dense_allreduce",
        "sparse_allreduce_shared" => "sparse_allreduce_shared",
        "sparse_gather" => "sparse_gather",
        other => anyhow::bail!("digest: unknown op '{other}'"),
    })
}

fn emit_step<W: Write>(out: &mut W, s: &StepDigest) -> anyhow::Result<()> {
    let (kind, sel) = match &s.kind {
        StepKind::Dense => ("dense".to_string(), "-".to_string()),
        StepKind::Shared(ix) => ("shared".to_string(), fmt_u32s(ix)),
        StepKind::Gather(per) => (
            "gather".to_string(),
            per.iter().map(|ix| fmt_u32s(ix)).collect::<Vec<_>>().join(";"),
        ),
    };
    writeln!(
        out,
        "step t={} leader={} kind={kind} sel={sel} vals={} op={} up={} down={} bn={} hops={} time={}",
        s.t,
        s.leader,
        fmt_f32s(&s.values),
        s.comm.op,
        s.comm.bytes_up_per_worker,
        s.comm.bytes_down_per_worker,
        s.comm.bottleneck_bytes,
        s.comm.hops,
        s.comm.time_s,
    )?;
    out.flush()?;
    Ok(())
}

/// Key=value accessor over one digest line's tokens.
fn kv<'a>(tokens: &'a [&'a str], key: &str) -> anyhow::Result<&'a str> {
    tokens
        .iter()
        .find_map(|t| t.strip_prefix(key).and_then(|r| r.strip_prefix('=')))
        .ok_or_else(|| anyhow::anyhow!("digest: missing {key}= field"))
}

/// Parse a coordinator's stdout back into a [`NodeDigest`]. Tolerates
/// interleaved non-digest lines; fails on a truncated digest (no
/// `digest-end`), which is how the tests detect a crashed coordinator.
pub fn parse_digest(text: &str) -> anyhow::Result<NodeDigest> {
    let mut workers: Option<usize> = None;
    let mut steps: Vec<StepDigest> = Vec::new();
    let mut final_memory: Option<Vec<f32>> = None;
    let mut ended = false;
    for line in text.lines() {
        let tokens: Vec<&str> = line.split_whitespace().collect();
        match tokens.first().copied() {
            Some("digest") => {
                workers = Some(kv(&tokens, "workers")?.parse()?);
            }
            Some("step") => {
                let t: usize = kv(&tokens, "t")?.parse()?;
                let leader: usize = kv(&tokens, "leader")?.parse()?;
                let sel = kv(&tokens, "sel")?;
                let kind = match kv(&tokens, "kind")? {
                    "dense" => StepKind::Dense,
                    "shared" => StepKind::Shared(parse_u32s(sel)?),
                    "gather" => StepKind::Gather(
                        sel.split(';')
                            .map(parse_u32s)
                            .collect::<anyhow::Result<Vec<_>>>()?,
                    ),
                    other => anyhow::bail!("digest: unknown step kind '{other}'"),
                };
                let comm = CommCost {
                    op: op_static(kv(&tokens, "op")?)?,
                    bytes_up_per_worker: kv(&tokens, "up")?.parse()?,
                    bytes_down_per_worker: kv(&tokens, "down")?.parse()?,
                    bottleneck_bytes: kv(&tokens, "bn")?.parse()?,
                    hops: kv(&tokens, "hops")?.parse()?,
                    time_s: kv(&tokens, "time")?.parse()?,
                };
                // A resumed run re-emits steps from its rollback point
                // (after a `resume from=` marker); the replay supersedes
                // the pre-fault emissions — the determinism contract makes
                // them identical, but the replayed lines are the ones the
                // finished run stands by.
                if t < steps.len() {
                    steps.truncate(t);
                }
                anyhow::ensure!(t == steps.len(), "digest: step {t} out of order");
                steps.push(StepDigest {
                    t,
                    leader,
                    kind,
                    values: parse_f32s(kv(&tokens, "vals")?)?,
                    comm,
                });
            }
            Some("mem0") => {
                final_memory = Some(parse_f32s(kv(&tokens, "vals")?)?);
            }
            Some("digest-end") => {
                let declared: usize = kv(&tokens, "steps")?.parse()?;
                anyhow::ensure!(
                    declared == steps.len(),
                    "digest: declared {declared} steps but parsed {}",
                    steps.len()
                );
                ended = true;
            }
            _ => {} // foreign output interleaved with the digest
        }
    }
    anyhow::ensure!(ended, "digest: truncated (no digest-end line)");
    Ok(NodeDigest {
        workers: workers.ok_or_else(|| anyhow::anyhow!("digest: no header line"))?,
        steps,
        final_memory_rank0: final_memory
            .ok_or_else(|| anyhow::anyhow!("digest: no mem0 line"))?,
    })
}

/// Render a [`NodeDigest`] back into the coordinator's line-oriented
/// text form. The serve daemon uses this for `JobDone` payloads, so a
/// client can [`parse_digest`] + [`compare_digests`] a served job
/// against a one-shot run of the same workload; round-trips exactly
/// through [`parse_digest`].
pub fn render_digest(d: &NodeDigest) -> anyhow::Result<String> {
    let mut out: Vec<u8> = Vec::new();
    writeln!(out, "digest v1 workers={}", d.workers)?;
    for s in &d.steps {
        emit_step(&mut out, s)?;
    }
    writeln!(out, "mem0 vals={}", fmt_f32s(&d.final_memory_rank0))?;
    writeln!(out, "digest-end steps={}", d.steps.len())?;
    Ok(String::from_utf8(out)?)
}

/// Hold two digests to the backend parity contract:
/// selections/leaders/`CommCost` **exact**; gather values and the final
/// memory **bit-identical** (worker-order reductions / per-worker local
/// math); dense- and shared-path values within the ring
/// reduction-order tolerance.
pub fn compare_digests(
    got: &NodeDigest,
    want: &NodeDigest,
    rtol: f32,
    atol: f32,
) -> anyhow::Result<()> {
    use crate::util::floats::allclose;
    anyhow::ensure!(
        got.workers == want.workers,
        "workers: {} vs {}",
        got.workers,
        want.workers
    );
    anyhow::ensure!(
        got.steps.len() == want.steps.len(),
        "step count: {} vs {}",
        got.steps.len(),
        want.steps.len()
    );
    for (a, b) in got.steps.iter().zip(&want.steps) {
        let t = b.t;
        anyhow::ensure!(a.leader == b.leader, "t={t}: leader {} vs {}", a.leader, b.leader);
        anyhow::ensure!(a.kind == b.kind, "t={t}: selection mismatch");
        anyhow::ensure!(
            a.comm == b.comm,
            "t={t}: CommCost mismatch: {:?} vs {:?}",
            a.comm,
            b.comm
        );
        anyhow::ensure!(
            a.values.len() == b.values.len(),
            "t={t}: value count {} vs {}",
            a.values.len(),
            b.values.len()
        );
        match &b.kind {
            StepKind::Gather(_) => anyhow::ensure!(
                a.values == b.values,
                "t={t}: gather values must be bit-identical"
            ),
            _ => {
                if let Err(i) = allclose(&a.values, &b.values, rtol, atol) {
                    anyhow::bail!(
                        "t={t}: ring value {i} out of tolerance: {} vs {}",
                        a.values[i],
                        b.values[i]
                    );
                }
            }
        }
    }
    anyhow::ensure!(
        got.final_memory_rank0 == want.final_memory_rank0,
        "final rank-0 memory diverged (it is pure per-worker math and must be \
         bit-identical)"
    );
    Ok(())
}

// ----------------------------------------------------------------------
// The gradient stream and the two digest producers
// ----------------------------------------------------------------------

/// The run's gradient stream: one continuous RNG, `n` worker gradients
/// drawn in worker order each step — every node regenerates the same
/// stream locally, so no gradient bytes cross the wire.
pub(crate) fn step_grads(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; dim];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect()
}

/// Run the workload on the in-process sequential backend and digest it —
/// the reference side of the multi-process parity lock.
pub fn sequential_digest(wl: &NodeWorkload, n: usize) -> anyhow::Result<NodeDigest> {
    wl.validate()?;
    anyhow::ensure!(n >= 1, "need at least one worker");
    let fabric = Fabric::new(FabricConfig {
        workers: n,
        topology: wl.topology,
        ..FabricConfig::default()
    });
    let mode = if wl.scheme == "none" {
        Mode::Dense
    } else {
        Mode::Compressed(make_compressor(&wl.scheme, wl.rate, wl.seed)?)
    };
    let mut coord = Coordinator::new(n, wl.dim, mode, wl.beta, wl.k(), fabric, wl.warmup);
    let mut rng = Rng::for_stream(wl.seed, n as u64);
    let mut steps = Vec::with_capacity(wl.steps);
    for t in 0..wl.steps {
        let grads = step_grads(&mut rng, n, wl.dim);
        let r = coord.step(t, &grads);
        let (kind, values) = if r.dense {
            (StepKind::Dense, r.update.clone())
        } else {
            match r.selection.as_ref().expect("compressed step has a selection") {
                Selection::Shared(ix) => (
                    StepKind::Shared(ix.clone()),
                    ix.iter().map(|&i| r.update[i as usize]).collect(),
                ),
                Selection::PerWorker(per) => {
                    let mut union: Vec<u32> = per.iter().flatten().copied().collect();
                    union.sort_unstable();
                    union.dedup();
                    (
                        StepKind::Gather(per.clone()),
                        union.iter().map(|&i| r.update[i as usize]).collect(),
                    )
                }
            }
        };
        steps.push(StepDigest {
            t,
            leader: r.leader,
            kind,
            values,
            comm: r.comm.clone(),
        });
    }
    Ok(NodeDigest {
        workers: n,
        steps,
        final_memory_rank0: coord.memory_snapshot()[0].memory().to_vec(),
    })
}

/// The dense-collective seam of the node driver: the flat ring or the
/// hierarchical ring-of-rings, picked by `--group-size` at rendezvous.
/// Both sides expose the same three collectives the driver needs, with
/// identical arithmetic up to f32 reduction order (the parity contract),
/// so the step loop is topology-blind.
enum RingHandle {
    Flat(crate::comm::socket::SocketRingNode),
    Hier(crate::comm::socket::SocketHierRingNode),
}

impl RingHandle {
    fn allreduce_avg(&mut self, buf: &mut [f32]) -> anyhow::Result<()> {
        match self {
            RingHandle::Flat(r) => r.allreduce_avg(buf),
            RingHandle::Hier(r) => r.allreduce_avg(buf),
        }
    }

    fn broadcast_indices(
        &mut self,
        leader: usize,
        own: Option<&[u32]>,
    ) -> anyhow::Result<Vec<u32>> {
        match self {
            RingHandle::Flat(r) => r.broadcast_indices(leader, own),
            RingHandle::Hier(r) => r.broadcast_indices(leader, own),
        }
    }

    fn resume_min_reduce(&mut self, own: u64) -> anyhow::Result<u64> {
        match self {
            RingHandle::Flat(r) => r.resume_min_reduce(own),
            RingHandle::Hier(r) => r.resume_min_reduce(own),
        }
    }
}

/// One coordination step over the live mesh — the body of the
/// [`run_node`] loop, factored out so the reconnect path can retry a
/// step after recovery. State mutation is transactional at step scope:
/// the EF-memory update happens only after every collective of the step
/// succeeded, so a fault leaves `mem` at the last completed step and the
/// resume rollback stays exact.
#[allow(clippy::too_many_arguments)]
fn drive_step<W: Write>(
    t: usize,
    grads: &[Vec<f32>],
    rank: usize,
    n: usize,
    k: usize,
    wl: &NodeWorkload,
    compressor: &mut Option<Box<dyn Compressor>>,
    mem: &mut EfMemory,
    ring: &mut RingHandle,
    star: &mut crate::comm::socket::SocketStarNode,
    fabric: &mut Option<Fabric>,
    out: &mut W,
) -> anyhow::Result<()> {
    use anyhow::Context;
    {
        let grad = &grads[rank];
        let leader = t % n;
        let dense = compressor.is_none() || t < wl.warmup;
        if dense {
            let mut buf = grad.clone();
            ring.allreduce_avg(&mut buf)
                .with_context(|| format!("step {t}: dense ring all-reduce"))?;
            if let Some(f) = fabric.as_mut() {
                let comm = f.record_dense_allreduce(n, wl.dim);
                emit_step(
                    out,
                    &StepDigest {
                        t,
                        leader,
                        kind: StepKind::Dense,
                        values: buf,
                        comm,
                    },
                )?;
            }
        } else {
            let comp = compressor.as_mut().expect("compressed path has a scheme");
            let ef = mem.ef_grad(grad);
            if comp.is_commutative() {
                // Shared-index path: the cyclic leader selects on its own
                // EF gradient and broadcasts the set around the ring
                // (Algorithm 1 line 6 / Eqn. 3).
                let own_sel = if rank == leader {
                    // `CltK::select` reads `ef_grads[t % n]`; handing it n
                    // views of the leader's own vector makes that exactly
                    // this node's EF gradient — what a real leader sees.
                    let views: Vec<&[f32]> = vec![ef.as_slice(); n];
                    match comp.select(t, &views, k) {
                        Selection::Shared(ix) => Some(ix),
                        Selection::PerWorker(_) => anyhow::bail!(
                            "scheme '{}' is commutative but produced per-worker sets",
                            wl.scheme
                        ),
                    }
                } else {
                    None
                };
                let idx = ring
                    .broadcast_indices(leader, own_sel.as_deref())
                    .with_context(|| format!("step {t}: index broadcast"))?;
                // Every legitimate selection is strictly increasing and
                // in-range; duplicates would silently double-apply the
                // EF-memory update, so reject malformed broadcasts here.
                anyhow::ensure!(
                    idx.iter().all(|&i| (i as usize) < wl.dim)
                        && idx.windows(2).all(|w| w[0] < w[1]),
                    "step {t}: malformed index broadcast (must be strictly \
                     increasing and < dim {})",
                    wl.dim
                );
                let mut vals: Vec<f32> = idx.iter().map(|&i| ef[i as usize]).collect();
                ring.allreduce_avg(&mut vals)
                    .with_context(|| format!("step {t}: sparse ring all-reduce"))?;
                mem.update_after_send(grad, &idx);
                if let Some(f) = fabric.as_mut() {
                    let comm = f.record_sparse_allreduce_shared(n, idx.len());
                    emit_step(
                        out,
                        &StepDigest {
                            t,
                            leader,
                            kind: StepKind::Shared(idx),
                            values: vals,
                            comm,
                        },
                    )?;
                }
            } else {
                // Per-worker path (local top-k): own selection, star
                // gather at the coordinator — the gradient build-up.
                let own_idx = match comp.select(t, &[ef.as_slice()], k) {
                    Selection::PerWorker(mut per) => per.remove(0),
                    Selection::Shared(_) => anyhow::bail!(
                        "scheme '{}' is non-commutative but produced a shared set",
                        wl.scheme
                    ),
                };
                let gathered = star
                    .gather(sparsify(&ef, &own_idx))
                    .with_context(|| format!("step {t}: star gather"))?;
                mem.update_after_send(grad, &own_idx);
                if let Some(f) = fabric.as_mut() {
                    let all = gathered.expect("rank 0 roots the star");
                    // A peer launched with a different --dim would send
                    // contributions the reduction cannot hold — surface
                    // the misconfiguration instead of panicking on it.
                    for (w, s) in all.iter().enumerate() {
                        anyhow::ensure!(
                            s.dim == wl.dim,
                            "step {t}: worker {w} sent a dim-{} contribution into a \
                             dim-{} run — every node must be launched with the same \
                             --dim",
                            s.dim,
                            wl.dim
                        );
                    }
                    // One shared definition of the gather arithmetic
                    // (worker-order root reduction) for every backend.
                    let (acc, gs) = crate::comm::fabric::reduce_gathered(&all, wl.dim);
                    let mut union: Vec<u32> =
                        all.iter().flat_map(|s| s.indices.iter().copied()).collect();
                    union.sort_unstable();
                    union.dedup();
                    let values = union.iter().map(|&i| acc[i as usize]).collect();
                    let comm = f.record_sparse_gather(&gs);
                    emit_step(
                        out,
                        &StepDigest {
                            t,
                            leader,
                            kind: StepKind::Gather(
                                all.iter().map(|s| s.indices.clone()).collect(),
                            ),
                            values,
                            comm,
                        },
                    )?;
                }
            }
        }
    }
    Ok(())
}

/// Agree on the fleet-wide resume point after a rendezvous and roll this
/// node's state back to it. Every node reports the next step its newest
/// snapshot can restore (`0` = from scratch), the ring min-reduces, and
/// the minimum wins: EF memory is restored from the in-memory ring (a
/// survivor) or the persisted file (a restarted process), and a fresh
/// gradient RNG is fast-forwarded past the replayed prefix — the stream
/// is one continuous generator, so resuming at step `M` means consuming
/// exactly the draws of steps `0..M`. Returns the step to continue from.
#[allow(clippy::too_many_arguments)]
fn agree_and_rollback<W: Write>(
    ring: &mut RingHandle,
    rank: usize,
    n: usize,
    wl: &NodeWorkload,
    mem: &mut EfMemory,
    rng: &mut Rng,
    snaps: &mut SnapshotRing,
    disk_dir: Option<&Path>,
    out: &mut W,
) -> anyhow::Result<usize> {
    use anyhow::Context;
    // Scan the whole on-disk ring, not just the newest file: a corrupt
    // or torn newest snapshot *degrades* this rank's claimed resume step
    // (the min-reduce then settles on a step everyone can restore)
    // instead of killing the rejoin.
    let disk_latest = disk_dir
        .and_then(|d| snapshot::latest_on_disk(d, rank))
        .map(|(s, _)| s);
    let own_next: u64 = snaps
        .latest_step()
        .or(disk_latest)
        .map(|s| s + 1)
        .unwrap_or(0);
    let resume = ring
        .resume_min_reduce(own_next)
        .context("resume agreement (ring min-reduce)")?;
    anyhow::ensure!(
        resume <= wl.steps as u64,
        "resume agreement past the end of the run: step {resume} > --steps {} \
         (a stale --snapshot-dir from a longer previous run?)",
        wl.steps
    );
    if resume == 0 {
        // From scratch: a member has no snapshot (cold start, or a
        // restarted process without --snapshot-dir) — everyone replays
        // the whole run, superseding any pre-fault digest emissions.
        *mem = EfMemory::new(wl.dim, wl.beta);
        *rng = Rng::for_stream(wl.seed, n as u64);
        *snaps = SnapshotRing::new(snapshot::DEFAULT_RING_DEPTH);
        return Ok(0);
    }
    let target = resume - 1; // restore the state AFTER this step
    let from_disk = match disk_dir {
        Some(d) => snapshot::load_at(d, rank, target)?,
        None => None,
    };
    let restored: EfMemory = if let Some(m) = snaps.get(target) {
        m.clone()
    } else if let Some(m) = from_disk {
        m
    } else {
        anyhow::bail!(
            "rank {rank}: no snapshot for step {target} (the fleet's resume point) \
             — it fell out of the in-memory ring and the on-disk ring's \
             {}-step window, or --snapshot-dir was not set; restart the whole run",
            snapshot::DEFAULT_RING_DEPTH
        );
    };
    anyhow::ensure!(
        restored.dim() == wl.dim,
        "rank {rank}: snapshot dim {} != --dim {} (snapshot from a different run?)",
        restored.dim(),
        wl.dim
    );
    *mem = restored;
    snaps.truncate_after(target);
    *rng = Rng::for_stream(wl.seed, n as u64);
    for _ in 0..resume {
        let _ = step_grads(rng, n, wl.dim);
    }
    if rank == 0 {
        writeln!(out, "resume from={resume}")?;
        out.flush()?;
    }
    Ok(resume as usize)
}

/// Run one node of the multi-process ring: bind, rendezvous, execute the
/// workload over the socket collectives. The coordinator (rank 0) books
/// the analytic `CommCost` through the same `Fabric::record_*` entry
/// points as every in-process backend and writes the digest to `out`;
/// workers only report completion. With `spec.reconnect` a link fault
/// triggers re-rendezvous on the same listener plus the resume protocol
/// (module docs) instead of failing the run.
pub fn run_node<W: Write>(spec: &NodeSpec, wl: &NodeWorkload, out: &mut W) -> anyhow::Result<()> {
    use anyhow::Context;
    wl.validate()?;
    let rank = spec.rank;
    let n = spec.workers();
    // Loud tiling check before any socket work: a group size that does
    // not tile the fleet must fail at launch on every node, identically.
    crate::comm::parallel::validate_group_size(n, spec.group_size)?;
    let hier = spec.group_size >= 2;
    // A restarted node races its predecessor's dying sockets for the
    // port (TIME_WAIT can linger); with reconnect on, keep knocking
    // until the rendezvous timeout instead of failing the relaunch.
    let listener = {
        let deadline = std::time::Instant::now() + spec.timeout;
        loop {
            match TcpListener::bind(spec.bind.as_str()) {
                Ok(l) => break l,
                Err(_) if spec.reconnect && std::time::Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => {
                    return Err(anyhow::Error::new(e)
                        .context(format!("rank {rank}: bind {}", spec.bind)));
                }
            }
        }
    };
    writeln!(out, "node rank={rank} n={n} bound={}", spec.bind)?;
    out.flush()?;
    let codec_stats = crate::comm::CodecStats::new();
    // One rendezvous seam for both topologies: the reconnect arm below
    // re-forms through the same closure, so a recovered mesh keeps the
    // ring-of-rings shape the run was launched with.
    let form = |listener: &TcpListener| -> anyhow::Result<(
        RingHandle,
        crate::comm::socket::SocketStarNode,
    )> {
        if hier {
            let (hier_ring, star) = crate::comm::socket::form_hier_mesh_with(
                rank,
                &spec.peers,
                spec.group_size,
                listener,
                spec.timeout,
                spec.wire_codec,
                &codec_stats,
                spec.heartbeat,
            )?;
            Ok((RingHandle::Hier(hier_ring), star))
        } else {
            let (ring, star) = form_mesh_with(
                rank,
                &spec.peers,
                listener,
                spec.timeout,
                spec.wire_codec,
                &codec_stats,
                spec.heartbeat,
            )?;
            Ok((RingHandle::Flat(ring), star))
        }
    };
    let (mut ring, mut star) = form(&listener)?;
    // Post-rendezvous: every rank passes this point right after its
    // Hello handshakes complete, so it is the shared clock event
    // `trace merge` aligns per-rank files on. Unconditional stores —
    // no-ops unless `--trace-out` armed the recorder.
    crate::obs::set_rank(rank as u32);
    crate::obs::mark_sync();

    let k = wl.k();
    let mut compressor = if wl.scheme == "none" {
        None
    } else {
        Some(make_compressor(&wl.scheme, wl.rate, wl.seed)?)
    };
    let mut mem = EfMemory::new(wl.dim, wl.beta);
    let mut fabric = (rank == 0).then(|| {
        Fabric::new(FabricConfig {
            workers: n,
            topology: wl.topology,
            ..FabricConfig::default()
        })
    });
    if rank == 0 {
        writeln!(
            out,
            "digest v1 workers={n} steps={} scheme={} dim={} rate={} seed={} warmup={}",
            wl.steps, wl.scheme, wl.dim, wl.rate, wl.seed, wl.warmup
        )?;
        out.flush()?;
    }

    let mut rng = Rng::for_stream(wl.seed, n as u64);
    let mut snaps = SnapshotRing::new(snapshot::DEFAULT_RING_DEPTH);
    let disk_dir = spec.snapshot_dir.as_deref();
    let mut attempts_left = spec.max_reconnect_attempts;
    let mut t: usize = 0;
    if spec.reconnect {
        // Uniform protocol: the resume exchange runs after EVERY
        // rendezvous, because a restarted member cannot know whether the
        // others are fresh or recovering. A cold start min-reduces to 0
        // and is a no-op (no marker), so the digest stays byte-identical
        // to a reconnect-less run.
        t = agree_and_rollback(
            &mut ring, rank, n, wl, &mut mem, &mut rng, &mut snaps, disk_dir, out,
        )?;
    }

    while t < wl.steps {
        // The whole step body — the optional drain ballot, then the
        // step's collectives — runs in one closure so a fault anywhere
        // in it rides the same reconnect arm below. `Ok(false)` means a
        // unanimous drain, not an error.
        let stepped = (|| -> anyhow::Result<bool> {
            if spec.graceful {
                // Drain ballot: one tiny ring min-reduce per boundary.
                // A rank that saw SIGINT/SIGTERM votes 0; a 0 minimum
                // drains EVERY rank at this same boundary, so no peer is
                // left blocked mid-collective and the mesh teardown is
                // clean EOFs, not RSTs.
                let vote: u64 = if crate::util::signal::shutdown_requested() {
                    0
                } else {
                    1
                };
                let fleet = ring
                    .resume_min_reduce(vote)
                    .with_context(|| format!("step {t}: shutdown drain ballot"))?;
                if fleet == 0 {
                    return Ok(false);
                }
            }
            let grads = step_grads(&mut rng, n, wl.dim);
            drive_step(
                t,
                &grads,
                rank,
                n,
                k,
                wl,
                &mut compressor,
                &mut mem,
                &mut ring,
                &mut star,
                &mut fabric,
                out,
            )?;
            Ok(true)
        })();
        match stepped {
            Ok(false) => {
                writeln!(out, "shutdown drained rank={rank} t={t}")?;
                out.flush()?;
                break;
            }
            Ok(true) => {
                if spec.reconnect {
                    snaps.push(t as u64, mem.clone());
                    if let Some(d) = disk_dir {
                        snapshot::save_ring(d, rank, t as u64, &mem)
                            .with_context(|| format!("rank {rank}: persist step {t} snapshot"))?;
                    }
                }
                if wl.step_delay_ms > 0 {
                    std::thread::sleep(Duration::from_millis(wl.step_delay_ms));
                }
                t += 1;
            }
            Err(e) if spec.reconnect && attempts_left > 0 => {
                attempts_left -= 1;
                writeln!(
                    out,
                    "health degraded rank={rank} t={t} attempts-left={attempts_left} err={e:#}"
                )?;
                out.flush()?;
                // Drop the faulted mesh BEFORE re-rendezvous: the EOFs
                // cascade the recovery wave to peers still blocked
                // mid-collective, so the whole fleet converges on
                // form_mesh within milliseconds of the first detection.
                drop(ring);
                drop(star);
                let refreshed = form(&listener)
                    .with_context(|| format!("rank {rank}: re-rendezvous after fault at step {t}"))?;
                ring = refreshed.0;
                star = refreshed.1;
                t = agree_and_rollback(
                    &mut ring, rank, n, wl, &mut mem, &mut rng, &mut snaps, disk_dir, out,
                )?;
            }
            Err(e) => return Err(e),
        }
    }
    // `t == wl.steps` on normal completion (byte-identical tail to
    // before); smaller after a graceful drain — and the `digest-end`
    // count is what `parse_digest` validates, so a drained run still
    // leaves a parseable digest of the steps that did complete.
    if rank == 0 {
        writeln!(out, "mem0 vals={}", fmt_f32s(mem.memory()))?;
        writeln!(out, "digest-end steps={t}")?;
    } else {
        writeln!(out, "node rank={rank} done steps={t}")?;
    }
    out.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn free_addrs(k: usize) -> Vec<String> {
        // Bind ephemeral listeners to reserve distinct ports, then free
        // them for run_node to re-bind (tiny race, negligible in tests).
        let listeners: Vec<TcpListener> = (0..k)
            .map(|_| TcpListener::bind(("127.0.0.1", 0)).unwrap())
            .collect();
        listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect()
    }

    fn spec_for(peers: &[String], rank: usize) -> NodeSpec {
        let role = if rank == 0 { "coordinator" } else { "worker" };
        NodeSpec::from_flags(
            Some(role),
            Some(&peers[rank]),
            Some(&peers.join(",")),
            Duration::from_secs(20),
        )
        .expect("valid spec")
    }

    /// Drive every rank on a thread inside this process; return the
    /// coordinator's parsed digest. `heartbeat`/`reconnect` configure the
    /// fault-tolerance layer on every rank.
    fn run_all_ranks_with(
        wl: &NodeWorkload,
        n: usize,
        heartbeat: Option<Duration>,
        reconnect: bool,
    ) -> NodeDigest {
        run_all_ranks_grouped(wl, n, heartbeat, reconnect, 0)
    }

    /// Like [`run_all_ranks_with`] with a `--group-size` axis (0 = flat,
    /// >= 2 = the hierarchical ring-of-rings mesh on every rank).
    fn run_all_ranks_grouped(
        wl: &NodeWorkload,
        n: usize,
        heartbeat: Option<Duration>,
        reconnect: bool,
        group_size: usize,
    ) -> NodeDigest {
        let peers = free_addrs(n);
        let outputs: Vec<Vec<u8>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let peers = &peers;
                    let wl = wl.clone();
                    s.spawn(move || {
                        let spec = spec_for(peers, rank)
                            .with_fault_tolerance(heartbeat, reconnect, None)
                            .with_group_size(group_size)
                            .expect("test tiling is valid");
                        let mut out = Vec::new();
                        run_node(&spec, &wl, &mut out)
                            .unwrap_or_else(|e| panic!("rank {rank}: {e:#}"));
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank")).collect()
        });
        parse_digest(&String::from_utf8(outputs[0].clone()).unwrap()).expect("digest")
    }

    fn run_all_ranks(wl: &NodeWorkload, n: usize) -> NodeDigest {
        run_all_ranks_with(wl, n, None, false)
    }

    #[test]
    fn spec_rejects_missing_or_inconsistent_flags_cleanly() {
        let t = Duration::from_secs(1);
        let err = NodeSpec::from_flags(Some("coordinator"), Some("a:1"), None, t).unwrap_err();
        assert!(err.to_string().contains("--peers"), "{err}");
        let err = NodeSpec::from_flags(None, Some("a:1"), Some("a:1"), t).unwrap_err();
        assert!(err.to_string().contains("--role"), "{err}");
        let err =
            NodeSpec::from_flags(Some("coordinator"), None, Some("a:1,b:2"), t).unwrap_err();
        assert!(err.to_string().contains("--bind"), "{err}");
        let err = NodeSpec::from_flags(Some("coordinator"), Some("c:3"), Some("a:1,b:2"), t)
            .unwrap_err();
        assert!(err.to_string().contains("does not appear"), "{err}");
        let err = NodeSpec::from_flags(Some("worker"), Some("a:1"), Some("a:1,b:2"), t)
            .unwrap_err();
        assert!(err.to_string().contains("coordinator"), "{err}");
        let err = NodeSpec::from_flags(Some("coordinator"), Some("b:2"), Some("a:1,b:2"), t)
            .unwrap_err();
        assert!(err.to_string().contains("first in --peers"), "{err}");
        let err = NodeSpec::from_flags(Some("coordinator"), Some("a:1"), Some("a:1,a:1"), t)
            .unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
        let ok = NodeSpec::from_flags(Some("worker"), Some("b:2"), Some("a:1, b:2"), t).unwrap();
        assert_eq!(ok.rank, 1);
        assert_eq!(ok.workers(), 2);
    }

    #[test]
    fn workload_rejects_unsupported_schemes() {
        let wl = NodeWorkload {
            scheme: "true-topk".into(),
            ..NodeWorkload::default()
        };
        let err = wl.validate().unwrap_err();
        assert!(err.to_string().contains("not runnable"), "{err}");
        NodeWorkload::default().validate().unwrap();
    }

    #[test]
    fn render_digest_round_trips_through_parse() {
        let wl = NodeWorkload {
            steps: 8,
            warmup: 2, // cover dense + compressed lines
            ..NodeWorkload::default()
        };
        let want = sequential_digest(&wl, 3).unwrap();
        let text = render_digest(&want).unwrap();
        let got = parse_digest(&text).unwrap();
        // Exact tolerance: the round trip re-parses the very same f32
        // formatting `run_node` emits, so nothing may move at all.
        compare_digests(&got, &want, 0.0, 0.0).unwrap();
        assert_eq!(got.final_memory_rank0, want.final_memory_rank0);
    }

    #[test]
    fn graceful_drain_exits_cleanly_with_parseable_digest() {
        // Serialize against every other test touching the process-global
        // shutdown flag, then latch it BEFORE launch: each rank votes 0
        // in its first drain ballot and the fleet drains unanimously at
        // t=0 — no rank errors, no latched fault, and rank 0 still
        // emits a digest that parses (0 completed steps).
        let _guard = crate::util::signal::test_guard();
        crate::util::signal::request_shutdown();
        let wl = NodeWorkload {
            steps: 10,
            ..NodeWorkload::default()
        };
        let n = 2;
        let peers = free_addrs(n);
        let outputs: Vec<Vec<u8>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let peers = &peers;
                    let wl = wl.clone();
                    s.spawn(move || {
                        let spec = spec_for(peers, rank).with_graceful(true);
                        let mut out = Vec::new();
                        run_node(&spec, &wl, &mut out)
                            .unwrap_or_else(|e| panic!("rank {rank}: drained run failed: {e:#}"));
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank")).collect()
        });
        crate::util::signal::clear_shutdown();
        let coord = String::from_utf8(outputs[0].clone()).unwrap();
        assert!(coord.contains("shutdown drained rank=0 t=0"), "{coord}");
        let d = parse_digest(&coord).expect("drained digest still parses");
        assert_eq!(d.steps.len(), 0, "drained before the first step");
        let worker = String::from_utf8(outputs[1].clone()).unwrap();
        assert!(worker.contains("shutdown drained rank=1 t=0"), "{worker}");
        assert!(worker.contains("done steps=0"), "{worker}");
    }

    #[test]
    fn graceful_ballot_without_shutdown_changes_nothing() {
        // graceful=true but no signal: the per-boundary ballot must be
        // digest-invisible — bit-identical to the plain run.
        let wl = NodeWorkload {
            steps: 6,
            warmup: 1,
            ..NodeWorkload::default()
        };
        let n = 2;
        let _guard = crate::util::signal::test_guard();
        let peers = free_addrs(n);
        let outputs: Vec<Vec<u8>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let peers = &peers;
                    let wl = wl.clone();
                    s.spawn(move || {
                        let spec = spec_for(peers, rank).with_graceful(true);
                        let mut out = Vec::new();
                        run_node(&spec, &wl, &mut out)
                            .unwrap_or_else(|e| panic!("rank {rank}: {e:#}"));
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank")).collect()
        });
        let got = parse_digest(&String::from_utf8(outputs[0].clone()).unwrap()).unwrap();
        let want = sequential_digest(&wl, n).unwrap();
        compare_digests(&got, &want, 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn in_process_nodes_match_sequential_digest_shared_path() {
        let wl = NodeWorkload {
            steps: 20,
            warmup: 3, // cover the dense → compressed transition
            ..NodeWorkload::default()
        };
        for n in [1usize, 2, 4] {
            let got = run_all_ranks(&wl, n);
            let want = sequential_digest(&wl, n).unwrap();
            compare_digests(&got, &want, 1e-5, 1e-6)
                .unwrap_or_else(|e| panic!("n={n}: {e:#}"));
        }
    }

    #[test]
    fn in_process_nodes_match_sequential_digest_gather_path() {
        let wl = NodeWorkload {
            scheme: "local-topk".into(),
            steps: 15,
            ..NodeWorkload::default()
        };
        let got = run_all_ranks(&wl, 3);
        let want = sequential_digest(&wl, 3).unwrap();
        compare_digests(&got, &want, 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn in_process_nodes_match_sequential_digest_dense_and_random() {
        for scheme in ["none", "random-k"] {
            let wl = NodeWorkload {
                scheme: scheme.into(),
                steps: 10,
                ..NodeWorkload::default()
            };
            let got = run_all_ranks(&wl, 2);
            let want = sequential_digest(&wl, 2).unwrap();
            compare_digests(&got, &want, 1e-5, 1e-6)
                .unwrap_or_else(|e| panic!("{scheme}: {e:#}"));
        }
    }

    #[test]
    fn digest_parse_detects_truncation() {
        let wl = NodeWorkload {
            steps: 4,
            ..NodeWorkload::default()
        };
        let want = sequential_digest(&wl, 2).unwrap();
        // emit a full digest, then chop the tail off
        let mut buf = Vec::new();
        writeln!(buf, "digest v1 workers=2 steps=4 scheme=x dim=96 rate=8 seed=42 warmup=0")
            .unwrap();
        for s in &want.steps {
            emit_step(&mut buf, s).unwrap();
        }
        let full = String::from_utf8(buf).unwrap();
        let err = parse_digest(&full).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn digest_emit_parse_roundtrips_exactly() {
        let wl = NodeWorkload {
            steps: 6,
            warmup: 2,
            ..NodeWorkload::default()
        };
        let want = sequential_digest(&wl, 3).unwrap();
        let mut buf = Vec::new();
        writeln!(buf, "digest v1 workers=3").unwrap();
        for s in &want.steps {
            emit_step(&mut buf, s).unwrap();
        }
        writeln!(buf, "mem0 vals={}", fmt_f32s(&want.final_memory_rank0)).unwrap();
        writeln!(buf, "digest-end steps={}", want.steps.len()).unwrap();
        let parsed = parse_digest(&String::from_utf8(buf).unwrap()).unwrap();
        // text round-trip must be lossless: compare at zero tolerance
        compare_digests(&parsed, &want, 0.0, 0.0).unwrap();
    }

    #[test]
    fn hier_nodes_match_sequential_digest() {
        // The hierarchical mesh must produce the sequential digest within
        // the parity contract: selections/leaders/CommCost exact, ring
        // values within f32 reduction-order tolerance — the index
        // broadcast and the 3-phase dense reduce are topology-internal.
        let wl = NodeWorkload {
            steps: 12,
            warmup: 2, // cover the dense → compressed transition
            ..NodeWorkload::default()
        };
        for (n, g) in [(4usize, 2usize), (8, 2), (8, 4)] {
            let got = run_all_ranks_grouped(&wl, n, None, false, g);
            let want = sequential_digest(&wl, n).unwrap();
            compare_digests(&got, &want, 1e-5, 1e-6)
                .unwrap_or_else(|e| panic!("n={n} g={g}: {e:#}"));
        }
    }

    #[test]
    fn hier_resume_exchange_keeps_parity() {
        // Heartbeats + the post-rendezvous resume min-reduce riding the
        // seeded two-pass hierarchy protocol must not perturb the digest.
        let wl = NodeWorkload {
            steps: 8,
            ..NodeWorkload::default()
        };
        let got =
            run_all_ranks_grouped(&wl, 4, Some(Duration::from_millis(100)), true, 2);
        let want = sequential_digest(&wl, 4).unwrap();
        compare_digests(&got, &want, 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn spec_rejects_untileable_group_sizes() {
        let peers = ["a:1", "b:2", "c:3", "d:4"].map(String::from);
        let spec = spec_for(&peers, 0);
        let err = spec.clone().with_group_size(3).unwrap_err();
        assert!(err.to_string().contains("does not divide"), "{err}");
        let err = spec.clone().with_group_size(4).unwrap_err();
        assert!(err.to_string().contains("at least 2 groups"), "{err}");
        assert_eq!(spec.clone().with_group_size(2).unwrap().group_size, 2);
        assert_eq!(spec.with_group_size(0).unwrap().group_size, 0);
    }

    #[test]
    fn heartbeat_and_cold_start_resume_exchange_keep_parity() {
        // The fault-tolerance layer at rest: heartbeats flowing on every
        // link and the post-rendezvous resume exchange (which must
        // min-reduce to 0 on a cold start) may not perturb the digest.
        let wl = NodeWorkload {
            steps: 12,
            warmup: 2,
            ..NodeWorkload::default()
        };
        let got = run_all_ranks_with(&wl, 3, Some(Duration::from_millis(100)), true);
        let want = sequential_digest(&wl, 3).unwrap();
        compare_digests(&got, &want, 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn rollback_restores_memory_and_fast_forwards_the_stream() {
        use crate::comm::socket::SocketRingNode;
        let wl = NodeWorkload::default();
        let n = 3;
        // A "survivor" holding snapshots after steps 0..=3 with marker
        // memories; the rollback must pick step 3 and replay from 4.
        let mut snaps = SnapshotRing::new(snapshot::DEFAULT_RING_DEPTH);
        for s in 0..4u64 {
            let mut m = EfMemory::new(wl.dim, wl.beta);
            m.set_memory(vec![s as f32; wl.dim]);
            snaps.push(s, m);
        }
        let mut solo = RingHandle::Flat(SocketRingNode::new(0, 1, None, None));
        let mut mem = EfMemory::new(wl.dim, wl.beta);
        let mut rng = Rng::for_stream(999, 999); // garbage pre-rollback state
        let mut out = Vec::new();
        let t = agree_and_rollback(
            &mut solo, 0, n, &wl, &mut mem, &mut rng, &mut snaps, None, &mut out,
        )
        .unwrap();
        assert_eq!(t, 4);
        assert_eq!(mem.memory(), &vec![3.0f32; wl.dim][..]);
        assert_eq!(snaps.latest_step(), Some(3));
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("resume from=4"), "{text}");
        // The RNG must sit exactly past the draws of steps 0..4.
        let mut want = Rng::for_stream(wl.seed, n as u64);
        for _ in 0..4 {
            let _ = step_grads(&mut want, n, wl.dim);
        }
        assert_eq!(
            step_grads(&mut rng, n, wl.dim),
            step_grads(&mut want, n, wl.dim),
            "fast-forwarded stream diverged"
        );
    }

    #[test]
    fn rollback_reloads_a_persisted_snapshot_and_rejects_stale_ones() {
        use crate::comm::socket::SocketRingNode;
        let wl = NodeWorkload::default();
        let dir = std::env::temp_dir().join("scalecom_socket_rollback_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut persisted = EfMemory::new(wl.dim, wl.beta);
        persisted.set_memory(vec![7.5; wl.dim]);
        snapshot::save_ring(&dir, 1, 5, &persisted).unwrap();
        // A "restarted process": empty in-memory ring, state on disk only.
        let mut snaps = SnapshotRing::new(snapshot::DEFAULT_RING_DEPTH);
        let mut solo = RingHandle::Flat(SocketRingNode::new(0, 1, None, None));
        let mut mem = EfMemory::new(wl.dim, wl.beta);
        let mut rng = Rng::for_stream(1, 1);
        let mut out = Vec::new();
        let t = agree_and_rollback(
            &mut solo, 1, 2, &wl, &mut mem, &mut rng, &mut snaps, Some(dir.as_path()), &mut out,
        )
        .unwrap();
        assert_eq!(t, 6);
        assert_eq!(mem.memory(), persisted.memory());
        assert!(out.is_empty(), "only rank 0 emits the resume marker");
        // A snapshot from past the end of this run's --steps is stale.
        snapshot::save_ring(&dir, 1, wl.steps as u64 + 10, &persisted).unwrap();
        let err = agree_and_rollback(
            &mut solo, 1, 2, &wl, &mut mem, &mut rng, &mut snaps, Some(dir.as_path()), &mut out,
        )
        .unwrap_err();
        assert!(err.to_string().contains("past the end"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn digest_parse_keeps_the_replay_of_a_resumed_run() {
        // A faulted-then-resumed coordinator re-emits steps from the
        // rollback point; the parser must keep the replayed lines and
        // the result must equal the fault-free digest exactly.
        let wl = NodeWorkload {
            steps: 4,
            ..NodeWorkload::default()
        };
        let want = sequential_digest(&wl, 2).unwrap();
        let mut buf = Vec::new();
        writeln!(buf, "digest v1 workers=2").unwrap();
        for s in &want.steps {
            emit_step(&mut buf, s).unwrap();
        }
        writeln!(buf, "health degraded rank=0 t=4 attempts-left=2 err=peer dead").unwrap();
        writeln!(buf, "resume from=2").unwrap();
        for s in &want.steps[2..] {
            emit_step(&mut buf, s).unwrap();
        }
        writeln!(buf, "mem0 vals={}", fmt_f32s(&want.final_memory_rank0)).unwrap();
        writeln!(buf, "digest-end steps={}", want.steps.len()).unwrap();
        let parsed = parse_digest(&String::from_utf8(buf).unwrap()).unwrap();
        compare_digests(&parsed, &want, 0.0, 0.0).unwrap();
    }

    #[test]
    fn env_heartbeat_is_strict() {
        // Env vars are process-global; touch the var briefly, mirroring
        // codec::tests::env_wire_compression_is_strict.
        std::env::set_var(ENV_HEARTBEAT_MS, "250");
        assert_eq!(env_heartbeat_ms().unwrap(), Some(250));
        std::env::set_var(ENV_HEARTBEAT_MS, "fast");
        assert!(env_heartbeat_ms().is_err(), "set-but-invalid must be loud");
        std::env::remove_var(ENV_HEARTBEAT_MS);
        assert_eq!(env_heartbeat_ms().unwrap(), None);
    }
}
