//! Runtime: PJRT client wrapper + artifact manifest.
//!
//! `Engine` loads the HLO-text artifacts that `make artifacts` produced
//! and exposes typed train/eval/compress/apply calls. Python never runs
//! here — the Rust binary is self-contained once `artifacts/` exists.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, LoadedModel};
pub use manifest::{Dtype, Manifest, ModelManifest, TensorSpec};

use std::path::Path;

/// Default artifacts directory (overridable via config / --artifacts).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    // Look relative to CWD first, then next to the executable's repo root.
    let cwd = Path::new("artifacts");
    if cwd.join("manifest.json").exists() {
        return cwd.to_path_buf();
    }
    if let Ok(exe) = std::env::current_exe() {
        for anc in exe.ancestors() {
            let cand = anc.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
        }
    }
    cwd.to_path_buf()
}
