//! Runtime: PJRT client wrapper, artifact manifest, and the worker
//! execution engines.
//!
//! `Engine` loads the HLO-text artifacts that `make artifacts` produced
//! and exposes typed train/eval/compress/apply calls. Python never runs
//! here — the Rust binary is self-contained once `artifacts/` exists.
//! `threaded` is the scoped thread-per-worker execution backend behind
//! `Backend::Threaded`; `pipelined` is the persistent double-buffering
//! worker pool behind `Backend::Pipelined` (see `comm::parallel` for the
//! collectives both run on; the same pool serves `Backend::Socket` over
//! a loopback TCP mesh). `socket` is the multi-process runtime behind
//! `scalecom node`: rendezvous, the per-node driver, and the parity
//! digest. `bucketed` holds the per-bucket exchange schedule
//! (backward-order walk, selection merge, cost aggregation) behind
//! `Coordinator::step_bucketed`.

pub mod bucketed;
pub mod engine;
pub mod manifest;
pub mod pipelined;
pub mod snapshot;
pub mod socket;
pub mod threaded;

pub use engine::{Engine, LoadedModel};
pub use manifest::{Dtype, Manifest, ModelManifest, TensorSpec};
pub use pipelined::WorkerPool;

use std::path::Path;

/// True when the PJRT artifacts exist. Bare checkouts don't have them
/// (they come from `make artifacts`), so artifact-dependent integration
/// tests call this and skip with a message instead of failing.
pub fn artifacts_present() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

/// Default artifacts directory (overridable via config / --artifacts).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    // Look relative to CWD first, then next to the executable's repo root.
    let cwd = Path::new("artifacts");
    if cwd.join("manifest.json").exists() {
        return cwd.to_path_buf();
    }
    if let Ok(exe) = std::env::current_exe() {
        for anc in exe.ancestors() {
            let cand = anc.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
        }
    }
    cwd.to_path_buf()
}
